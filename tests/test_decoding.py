"""Decoding algorithms + composability with masks (paper's generality
claim: greedy/sampling/beam all operate on V_k)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade gracefully: only @given tests skip
    from tests._hypothesis_stub import given, settings, st

from repro.core.decoding import (DecodeConfig, NEG_INF, apply_bool_mask,
                                 beam_search, greedy, sample, select_batch,
                                 topk_topp_filter, union_packed_rows,
                                 unpack_mask_words)


def test_greedy_respects_mask():
    logits = jnp.asarray([[5.0, 1.0, 3.0]])
    mask = jnp.asarray([[False, True, True]])
    assert int(greedy(apply_bool_mask(logits, mask))[0]) == 2


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 10 ** 6),
       temp=st.floats(0.2, 2.0),
       k=st.integers(1, 8))
def test_sampling_never_picks_masked(seed, temp, k):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(2, 32)).astype(np.float32))
    mask = jnp.asarray(rng.integers(0, 2, size=(2, 32)).astype(bool))
    mask = mask.at[:, 0].set(True)  # at least one allowed
    masked = apply_bool_mask(logits, mask)
    t = sample(masked, jax.random.PRNGKey(seed), temperature=temp, top_k=k)
    for b in range(2):
        assert bool(mask[b, int(t[b])])


def test_top_p_limits_support():
    logits = jnp.asarray([[10.0, 1.0, 0.5, 0.1]])
    picks = set()
    for s in range(50):
        t = sample(logits, jax.random.PRNGKey(s), top_p=0.5)
        picks.add(int(t[0]))
    assert picks == {0}


def test_unpack_roundtrip():
    rng = np.random.default_rng(0)
    words = jnp.asarray(rng.integers(0, 2 ** 32, (3, 4), dtype=np.uint32))
    bits = unpack_mask_words(words, 128)
    ref = np.unpackbits(np.asarray(words).view(np.uint8),
                        bitorder="little").reshape(3, 128)
    np.testing.assert_array_equal(np.asarray(bits), ref.astype(bool))


def test_union_packed_rows_matches_numpy():
    rng = np.random.default_rng(1)
    store = rng.integers(0, 2 ** 32, (20, 4), dtype=np.uint32)
    rows = rng.integers(-1, 20, (5, 6)).astype(np.int32)
    out = np.asarray(union_packed_rows(jnp.asarray(store),
                                       jnp.asarray(rows)))
    for b in range(5):
        want = np.zeros(4, np.uint32)
        for r in rows[b]:
            if r >= 0:
                want |= store[r]
        np.testing.assert_array_equal(out[b], want)


def test_beam_search_with_mask():
    """Toy LM over 4 tokens; beam must find the highest-scoring sequence
    among mask-allowed ones and stop at EOS (id 1)."""
    table = {
        (): np.asarray([0.1, 0.0, 2.0, 1.9]),
        (2,): np.asarray([0.0, 3.0, 0.1, 0.2]),
        (3,): np.asarray([0.0, 5.0, 0.1, 0.2]),
    }

    def step(state, toks):
        logp = table.get(tuple(toks), np.asarray([0.0, 4.0, 0.0, 0.0]))
        lp = logp - np.log(np.exp(logp).sum())
        lp[0] = -1e30  # mask token 0 (grammar mask composes here)
        return lp, state

    beams = beam_search(step, None, beam_width=2, max_steps=4, eos_id=1)
    best = beams[0][0]
    assert best[-1] == 1 and 0 not in best
    assert best[0] == 3  # (3,)->EOS scores higher than (2,)->EOS


# ----------------------- batched per-row selector --------------------------

def _batch_params(configs):
    g, t, k, p = DecodeConfig.batch_arrays(configs)
    return (jnp.asarray(g), jnp.asarray(t), jnp.asarray(k), jnp.asarray(p))


def _keys(n, seed=0):
    return jnp.asarray(
        np.stack([np.full(n, seed, np.uint32),
                  np.arange(n, dtype=np.uint32)], axis=1))


def test_select_batch_never_picks_masked():
    rng = np.random.default_rng(0)
    B, V = 6, 64
    logits = jnp.asarray(rng.normal(size=(B, V)).astype(np.float32))
    mask = rng.integers(0, 2, size=(B, V)).astype(bool)
    mask[:, 0] = True
    masked = apply_bool_mask(logits, jnp.asarray(mask))
    cfgs = [DecodeConfig(method="sample", temperature=0.5 + 0.2 * b)
            for b in range(B)]
    for s in range(8):
        ids = np.asarray(select_batch(masked, _keys(B, s),
                                      *_batch_params(cfgs)))
        for b in range(B):
            assert mask[b, ids[b]], (b, ids[b])


def test_select_batch_greedy_rows_match_argmax():
    rng = np.random.default_rng(1)
    logits = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    cfgs = [DecodeConfig(method="greedy"),
            DecodeConfig(method="sample", temperature=2.0),
            DecodeConfig(method="greedy"),
            DecodeConfig(method="sample", top_k=3)]
    ids = np.asarray(select_batch(logits, _keys(4), *_batch_params(cfgs)))
    want = np.asarray(jnp.argmax(logits, axis=-1))
    assert ids[0] == want[0] and ids[2] == want[2]


def test_select_batch_per_row_top_k():
    """Row 0 has top_k=1 (must take the max); row 1 unrestricted."""
    logits = jnp.asarray([[0.0, 5.0, 4.9, 4.8],
                          [0.0, 5.0, 4.9, 4.8]])
    cfgs = [DecodeConfig(method="sample", temperature=1.0, top_k=1),
            DecodeConfig(method="sample", temperature=1.0)]
    picks0 = set()
    for s in range(30):
        ids = np.asarray(select_batch(logits, _keys(2, s),
                                      *_batch_params(cfgs)))
        picks0.add(int(ids[0]))
    assert picks0 == {1}


def test_select_batch_per_row_top_p():
    """A dominant token with top_p=0.5 is the only possible pick."""
    logits = jnp.asarray([[10.0, 1.0, 0.5, 0.1]])
    cfgs = [DecodeConfig(method="sample", top_p=0.5)]
    picks = set()
    for s in range(30):
        ids = np.asarray(select_batch(logits, _keys(1, s),
                                      *_batch_params(cfgs)))
        picks.add(int(ids[0]))
    assert picks == {0}


def test_batch_arrays_roundtrip():
    g, t, k, p = DecodeConfig.batch_arrays(
        [DecodeConfig(method="greedy"),
         DecodeConfig(method="sample", temperature=0.7, top_k=5, top_p=0.9)])
    np.testing.assert_array_equal(g, [True, False])
    np.testing.assert_allclose(t, [1.0, 0.7])
    np.testing.assert_array_equal(k, [0, 5])
    np.testing.assert_allclose(p, [1.0, 0.9])
    with pytest.raises(ValueError):
        DecodeConfig.batch_arrays([DecodeConfig(method="beam")])


def test_decode_config_dispatch():
    logits = jnp.asarray([[1.0, 9.0, 2.0]])
    assert int(DecodeConfig(method="greedy").select(logits)[0]) == 1
    t = DecodeConfig(method="sample", temperature=0.01).select(
        logits, jax.random.PRNGKey(0))
    assert int(t[0]) == 1


# ------------- scalar sampler <-> batched selector parity -------------------
# The scalar `sample` (sequential engine, DecodeConfig.select) and the
# batched `select_batch` (batched/paged/sharded engines) must keep
# IDENTICAL token-support sets for identical configs — they share
# `topk_topp_filter`, and these tests pin the boundary semantics
# (cum < top_p cutoff, inclusive-first-over token, tie handling).

def _scalar_support(logits_row, temp, top_k, top_p):
    """Token set the scalar sampler can draw from."""
    s = jnp.asarray(logits_row)[None, :] / max(temp, 1e-6)
    f = topk_topp_filter(
        s, jnp.full((1,), top_k or 0, jnp.int32),
        jnp.full((1,), 1.0 if top_p is None else top_p, jnp.float32))
    return set(np.where(np.asarray(f)[0] > NEG_INF / 2)[0].tolist())


def _batch_support(logits_row, temp, top_k, top_p):
    """Token set `select_batch` can draw from (its exact filter chain)."""
    s = jnp.asarray(logits_row)[None, :] / \
        jnp.maximum(jnp.asarray([temp], jnp.float32), 1e-6)[:, None]
    f = topk_topp_filter(s, jnp.asarray([top_k or 0], jnp.int32),
                         jnp.asarray([1.0 if top_p is None else top_p],
                                     jnp.float32))
    return set(np.where(np.asarray(f)[0] > NEG_INF / 2)[0].tolist())


@settings(max_examples=60, deadline=None)
@given(seed=st.integers(0, 10 ** 6),
       temp=st.floats(0.1, 3.0),
       top_k=st.one_of(st.none(), st.integers(1, 40)),
       top_p=st.one_of(st.none(), st.floats(0.05, 1.0)))
def test_scalar_batch_topp_support_parity(seed, temp, top_k, top_p):
    """Fuzz across temperatures/top-k/top-p (incl. the top_p == 1.0 and
    ties boundaries): both samplers must keep the same token set."""
    rng = np.random.default_rng(seed)
    V = 64
    logits = rng.normal(size=V).astype(np.float32)
    # inject ties at the top-k and nucleus boundaries half the time
    if seed % 2:
        order = np.argsort(logits)[::-1]
        logits[order[1]] = logits[order[2]]
        logits[order[4]] = logits[order[5]]
    assert _scalar_support(logits, temp, top_k, top_p) == \
        _batch_support(logits, temp, top_k, top_p)


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 10 ** 6),
       temp=st.floats(0.2, 2.0),
       top_k=st.one_of(st.none(), st.integers(1, 16)),
       top_p=st.one_of(st.none(), st.floats(0.1, 1.0)))
def test_scalar_sample_draws_within_batch_support(seed, temp, top_k, top_p):
    """End-to-end: tokens the scalar sampler actually draws always lie in
    the batched selector's support set (and vice versa by symmetry of the
    shared filter)."""
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(1, 48)).astype(np.float32))
    sup = _batch_support(np.asarray(logits)[0], temp, top_k, top_p)
    for s in range(4):
        t = int(sample(logits, jax.random.PRNGKey(seed + s),
                       temperature=temp, top_k=top_k, top_p=top_p)[0])
        assert t in sup


def test_topp_one_keeps_full_support():
    """top_p=1.0 must disable the nucleus filter EXACTLY: the scalar
    sampler used to apply `cum < 1.0` literally, where cumsum round-off
    truncated low-probability tail tokens that `select_batch` kept."""
    logits = np.zeros(32, np.float32)
    logits[0] = 20.0            # softmax mass concentrates; cum hits 1.0
    assert _scalar_support(logits, 1.0, None, 1.0) == set(range(32))
    assert _batch_support(logits, 1.0, None, 1.0) == set(range(32))


def test_topp_inclusive_first_over_and_ties():
    """cum < top_p cutoff keeps the first token AT/OVER the boundary,
    plus any token tied with the cutoff logit — in both samplers."""
    logits = np.asarray([2.0, 1.0, 1.0, -3.0], np.float32)
    # p tiny: only the argmax survives (it is the first-over token)
    assert _scalar_support(logits, 1.0, None, 0.01) == {0}
    assert _batch_support(logits, 1.0, None, 0.01) == {0}
    # boundary inside the tied pair: the cutoff token's tie survives too
    s_sc = _scalar_support(logits, 1.0, None, 0.8)
    s_ba = _batch_support(logits, 1.0, None, 0.8)
    assert s_sc == s_ba == {0, 1, 2}
