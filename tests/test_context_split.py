"""Context-split differential: the precomputed CI rows + CD residue
overlay must reproduce the legacy per-accept-sequence mask BITWISE.

The legacy contract (one M0/M1 store row per accept sequence, no
classification) is re-derived here from first principles via
store.row_m0/row_m1 — the per-terminal row addressing survives exactly
so this oracle stays expressible. The split path under test is the one
the serving engine ships to the device: `step_rows` (CI row ids + cd
overlay words) unioned by `union_packed`.

Covers every builtin grammar x both approximation families, at token
boundaries AND adversarial mid-token byte cuts (deterministic sweeps
plus hypothesis), and locks in the economics of the split: the
context-dependent residue the host must still touch per step stays a
few percent of the vocab — that bound is WHY ci_lookup is cheap.
"""
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade gracefully: only @given tests skip
    from tests._hypothesis_stub import given, settings, st

from repro.core.constrain import GrammarConstraint
from repro.core.grammars import BUILTIN
from repro.core.mask_store import CD_ROW_THRESHOLD
from repro.core.sampling import GrammarSampler
from repro.core.tokenizer import EOS_ID


def legacy_union(gc, text: bytes):
    """Pre-split reference: union of one M0/M1 row per accept sequence
    (dropping sequences whose remainder walk dies), exactly what
    step_rows emitted before the context split."""
    res = gc.parser.partial_parse(text)
    r = res.remainder
    g, store = gc.grammar, gc.store
    strict = gc.mode == "grammar_strict"
    rows, walked = [], {}
    for seq in res.accept_sequences:
        t1 = seq[0]
        q = walked.get(t1)
        if q is None:
            dfa = g.terminals[t1].dfa
            q = dfa.walk_live(dfa.start, r)
            walked[t1] = q = int(q) if dfa.live[q] else -1
        if q < 0:
            continue
        rows.append(store.row_m0(t1, q, strict=strict) if len(seq) == 1
                    else store.row_m1(t1, q, seq[1], strict=strict))
    packed = store.union_rows(np.asarray(rows or [-1], np.int32))
    return packed, res.eos_allowed


def _assert_split_matches(gc, text: bytes):
    sm = gc.step_rows(text)
    got = gc.union_packed(sm)
    want, eos = legacy_union(gc, text)
    np.testing.assert_array_equal(got, want, err_msg=repr(text))
    assert sm.eos_allowed == eos, text


# ---------------- deterministic: all builtins x both modes ---------------

@pytest.mark.parametrize("mode", GrammarConstraint.MODES)
@pytest.mark.parametrize("name", BUILTIN)
def test_split_union_equals_legacy(name, mode, grammar_bundle, tokenizer):
    """Every token-boundary cut of sampled programs plus random BYTE
    cuts (mid-token = the adversarial case for residue selection)."""
    g, tab, store, _ = grammar_bundle(name)
    gc = GrammarConstraint(g, tab, store, tokenizer, mode=mode)
    sampler = GrammarSampler(g, seed=11)
    rng = np.random.default_rng(11)
    checked = 0
    for _ in range(4):
        prog = sampler.sample(14, max_bytes=200)
        prefix = b""
        for tid in tokenizer.encode(prog):
            _assert_split_matches(gc, prefix)
            prefix += tokenizer.id_to_bytes[tid]
            checked += 1
        for cut in rng.integers(0, len(prog) + 1, size=8):
            try:
                gc.parser.partial_parse(prog[:int(cut)])
            except Exception:
                continue            # unparseable cut: nothing to compare
            _assert_split_matches(gc, prog[:int(cut)])
            checked += 1
    assert checked > 30


def test_token_mask_unchanged_by_split(grammar_bundle, tokenizer):
    """End-to-end boolean mask: rows+overlay through unpack must equal
    the legacy union through unpack, EOS bit included."""
    g, tab, store, gc = grammar_bundle("json")
    for text in (b"", b"{", b'{"a": [1, ', b'{"k": {"x": true'):
        m = gc.token_mask(text)
        want, eos = legacy_union(gc, text)
        ref = store.unpack(want)
        if eos:
            ref[EOS_ID] = True
        np.testing.assert_array_equal(m, ref, err_msg=repr(text))


# ---------------- the residue stays small (the split's economics) --------

@pytest.mark.parametrize("name", BUILTIN)
def test_cd_residue_fraction_small(name, grammar_bundle, tokenizer):
    """At every sampled cut the CD overlay — the only per-step host work
    proportional to mask content — must stay a few percent of the
    vocab. The CI rows carry everything else, precomputed."""
    g, tab, store, gc = grammar_bundle(name)
    sampler = GrammarSampler(g, seed=5)
    V = tokenizer.vocab_size
    budget = max(2 * CD_ROW_THRESHOLD, int(0.05 * V))
    worst = 0
    for _ in range(4):
        prog = sampler.sample(14, max_bytes=200)
        prefix = b""
        for tid in tokenizer.encode(prog):
            sm = gc.step_rows(prefix)
            if sm.cd_words is not None:
                worst = max(worst, store.popcount_packed(sm.cd_words))
            prefix += tokenizer.id_to_bytes[tid]
    assert worst <= budget, (name, worst, budget)


def test_cd_tables_respect_threshold(grammar_bundle):
    """Offline classification invariant: per (state, follow terminal)
    the small-residue token count is <= CD_ROW_THRESHOLD (bigger
    residues must have been demoted to cd_big legacy rows instead).
    A state's cd_token slice aggregates across follows, so the bound is
    on each follow-bit column of cd_follow, not on the slice length."""
    for name in BUILTIN:
        _, _, store, _ = grammar_bundle(name)
        for i in range(len(store.cd_ptr) - 1):
            lo, hi = int(store.cd_ptr[i]), int(store.cd_ptr[i + 1])
            if hi <= lo:
                continue
            fol = store.cd_follow[lo:hi]
            for w in range(fol.shape[1]):
                for j in range(64):
                    cnt = int(((fol[:, w] >> np.uint64(j))
                               & np.uint64(1)).sum())
                    assert cnt <= CD_ROW_THRESHOLD, (name, i, w, j, cnt)


# ---------------- hypothesis: random grammar/seed/cut --------------------

@settings(deadline=None, max_examples=20)
@given(st.sampled_from(["calc", "json", "python_mini"]),
       st.sampled_from(GrammarConstraint.MODES),
       st.integers(0, 10 ** 6), st.data())
def test_fuzz_split_union_equals_legacy(name, mode, seed, data):
    from repro.core.grammars import load_grammar
    from repro.core.mask_store import build_mask_store
    from repro.core.tokenizer import ByteTokenizer
    from tests.conftest import _BUNDLES
    if name not in _BUNDLES:
        tok = ByteTokenizer(1024)
        g, tab = load_grammar(name)
        store = build_mask_store(g, tok)
        _BUNDLES[name] = (g, tab, store,
                          GrammarConstraint(g, tab, store, tok))
    g, tab, store, base = _BUNDLES[name]
    gc = GrammarConstraint(g, tab, store, base.tokenizer, mode=mode)
    prog = GrammarSampler(g, seed=seed).sample(14, max_bytes=200)
    cut = data.draw(st.integers(0, len(prog)))
    prefix = prog[:cut]
    try:
        gc.parser.partial_parse(prefix)
    except Exception:
        return                      # unparseable mid-byte cut: no mask
    _assert_split_matches(gc, prefix)
