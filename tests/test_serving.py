"""Serving engine end-to-end: the paper's core claim at system level —
every COMPLETED generation is syntactically valid; partial outputs stay
in L_p(G) at every step."""
import jax
import pytest

from repro.core.decoding import DecodeConfig
from repro.core.parser import IncrementalParser
from repro.serving.engine import Engine, Request


@pytest.fixture(scope="module")
def demo_engine(tokenizer):
    from repro.configs import get_config
    from repro.models.model import build_model
    from tests.conftest import _BUNDLES

    # reuse session-level grammar bundles via the factory fixture pattern
    from repro.core.grammars import load_grammar
    from repro.core.mask_store import build_mask_store
    bundles = {}
    for name in ("json", "calc"):
        g, tab = load_grammar(name)
        bundles[name] = (g, tab, build_mask_store(g, tokenizer))
    cfg = get_config("syncode-demo")
    from dataclasses import replace
    cfg = replace(cfg, vocab_size=tokenizer.vocab_size, num_layers=2,
                  d_model=128, d_ff=256, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return Engine(model, params, tokenizer, bundles, max_len=200), bundles


def test_constrained_outputs_always_valid(demo_engine):
    engine, bundles = demo_engine
    reqs = [Request(rid=i, prompt=b"say:", grammar="json",
                    max_new_tokens=40,
                    decode=DecodeConfig(method="sample", temperature=1.0),
                    seed=i) for i in range(4)]
    states, stats = engine.generate(reqs)
    g, tab, _ = bundles["json"]
    p = IncrementalParser(g, tab)
    for st in states:
        assert st.finish_reason in ("eos", "length", "max_len")
        if st.finish_reason == "eos":
            assert p.recognize(st.generated), st.generated
        else:
            # partial outputs must be in L_p(G): partial_parse succeeds
            p2 = IncrementalParser(g, tab)
            p2.partial_parse(st.generated)   # raises if not


def test_unconstrained_random_model_breaks_grammar(demo_engine):
    """Sanity: without the mask, a random model essentially never emits
    valid JSON (the paper's standard-generation row)."""
    engine, bundles = demo_engine
    reqs = [Request(rid=i, prompt=b"say:", grammar=None, max_new_tokens=30,
                    decode=DecodeConfig(method="sample", temperature=1.0),
                    seed=100 + i) for i in range(3)]
    states, _ = engine.generate(reqs)
    g, tab, _ = bundles["json"]
    p = IncrementalParser(g, tab)
    assert sum(p.recognize(st.generated) for st in states) == 0


def test_opportunistic_masking_same_guarantees(demo_engine, tokenizer):
    engine, bundles = demo_engine
    engine.opportunistic = True
    try:
        reqs = [Request(rid=i, prompt=b"say:", grammar="calc",
                        max_new_tokens=30,
                        decode=DecodeConfig(method="sample",
                                            temperature=1.0),
                        seed=i) for i in range(3)]
        states, stats = engine.generate(reqs)
        g, tab, _ = bundles["calc"]
        p = IncrementalParser(g, tab)
        for st in states:
            if st.finish_reason == "eos":
                assert p.recognize(st.generated)
        # the fast path must actually fire sometimes
        assert stats.opportunistic_hits + stats.mask_computations == \
            stats.tokens
    finally:
        engine.opportunistic = False


def test_greedy_deterministic(demo_engine):
    engine, bundles = demo_engine
    out = []
    for _ in range(2):
        reqs = [Request(rid=0, prompt=b"x:", grammar="calc",
                        max_new_tokens=20,
                        decode=DecodeConfig(method="greedy"), seed=0)]
        states, _ = engine.generate(reqs)
        out.append(states[0].generated)
    assert out[0] == out[1]


# ----------------------- batched continuous batching -----------------------

def test_batched_more_requests_than_slots(demo_engine):
    """Continuous batching: with more requests than decode slots, finished
    requests are immediately replaced and every request still completes
    with the soundness guarantee intact."""
    engine, bundles = demo_engine
    n = 2 * engine.slots + 1
    reqs = [Request(rid=i, prompt=b"say:", grammar="json",
                    max_new_tokens=12,
                    decode=DecodeConfig(method="sample", temperature=1.0),
                    seed=10 + i) for i in range(n)]
    states, stats = engine.generate(reqs)
    assert len(states) == n
    assert sorted(s.req.rid for s in states) == list(range(n))
    assert stats.batch_slots == engine.slots
    g, tab, _ = bundles["json"]
    for st in states:
        assert st.finish_reason in ("eos", "length", "max_len")
        if st.finish_reason == "eos":
            assert IncrementalParser(g, tab).recognize(st.generated)
        else:
            IncrementalParser(g, tab).partial_parse(st.generated)


def test_batched_shares_decode_steps(demo_engine):
    """The whole pool advances per device step: B concurrent requests must
    need far fewer decode calls than the sum of their generated tokens."""
    engine, bundles = demo_engine
    n = engine.slots
    reqs = [Request(rid=i, prompt=b"say:", grammar="calc",
                    max_new_tokens=15,
                    decode=DecodeConfig(method="sample", temperature=1.0),
                    seed=i) for i in range(n)]
    states, stats = engine.generate(reqs)
    assert stats.tokens == sum(s.steps for s in states)
    # one [B,V] decode serves all slots: steps ~ max per-request length,
    # not the sum of lengths
    assert stats.decode_steps <= max(s.steps for s in states) + n
    assert stats.decode_steps < stats.tokens


def test_batched_mixed_grammars_one_pool(demo_engine):
    """Slots with different grammars (and an unconstrained slot) share one
    fused mask call via the concatenated store + per-slot row offsets."""
    engine, bundles = demo_engine
    specs = [("json", 0), ("calc", 1), (None, 2), ("json", 3)]
    reqs = [Request(rid=i, prompt=b"say:", grammar=gname,
                    max_new_tokens=14,
                    decode=DecodeConfig(method="sample", temperature=1.0),
                    seed=40 + i) for gname, i in specs]
    states, _ = engine.generate(reqs)
    for st in states:
        if st.req.grammar is None:
            continue
        g, tab, _ = bundles[st.req.grammar]
        p = IncrementalParser(g, tab)
        if st.finish_reason == "eos":
            assert p.recognize(st.generated), (st.req.grammar, st.generated)
        else:
            p.partial_parse(st.generated)   # raises if not in L_p(G)


def test_batched_per_request_sampling_params(demo_engine):
    """Per-slot temperature/top-k/top-p ride the vmapped selector; the
    soundness guarantee must hold for every combination."""
    engine, bundles = demo_engine
    decodes = [DecodeConfig(method="greedy"),
               DecodeConfig(method="sample", temperature=0.7, top_k=8),
               DecodeConfig(method="sample", temperature=1.3, top_p=0.9),
               DecodeConfig(method="sample", temperature=1.0, top_k=4,
                            top_p=0.8)]
    reqs = [Request(rid=i, prompt=b"say:", grammar="json",
                    max_new_tokens=12, decode=dc, seed=60 + i)
            for i, dc in enumerate(decodes)]
    states, _ = engine.generate(reqs)
    g, tab, _ = bundles["json"]
    for st in states:
        if st.finish_reason == "eos":
            assert IncrementalParser(g, tab).recognize(st.generated)
        else:
            IncrementalParser(g, tab).partial_parse(st.generated)


def test_sequential_path_still_works(demo_engine):
    """generate_sequential stays the behavioral oracle for the scheduler."""
    engine, bundles = demo_engine
    reqs = [Request(rid=i, prompt=b"say:", grammar="json",
                    max_new_tokens=10,
                    decode=DecodeConfig(method="sample", temperature=1.0),
                    seed=80 + i) for i in range(2)]
    states, stats = engine.generate_sequential(reqs)
    g, tab, _ = bundles["json"]
    for st in states:
        if st.finish_reason == "eos":
            assert IncrementalParser(g, tab).recognize(st.generated)
    assert stats.batch_slots == 1


def test_step_rows_batch_matches_single():
    """The batched host-side Algorithm 2 must agree row-for-row with the
    per-sequence step_rows (including the concatenated-store offsets)."""
    import numpy as np
    from repro.core.constrain import GrammarConstraint
    from repro.core.grammars import load_grammar
    from repro.core.mask_store import build_mask_store
    from repro.core.tokenizer import ByteTokenizer
    tok = ByteTokenizer(1024)
    cons, texts = [], []
    for name, text in (("json", b'{"a": [1'), ("calc", b"math_sqrt(2")):
        g, tab = load_grammar(name)
        store = build_mask_store(g, tok)
        cons.append(GrammarConstraint(g, tab, store, tok))
        texts.append(text)
    cons.append(None)
    texts.append(b"")
    offs = np.array([0, 1000, 0])
    rows, cd, eos, nseq = GrammarConstraint.step_rows_batch(
        cons, texts, max_accept=48, row_offsets=offs)
    assert rows.shape == (3, 48) and eos.shape == (3,)
    for b in (0, 1):
        sm = cons[b].step_rows(texts[b])
        want = np.where(sm.rows >= 0, sm.rows + offs[b], sm.rows)
        np.testing.assert_array_equal(rows[b], want)
        assert eos[b] == sm.eos_allowed and nseq[b] == sm.num_sequences
        want_cd = (np.zeros_like(cd[b]) if sm.cd_words is None
                   else sm.cd_words)
        np.testing.assert_array_equal(cd[b], want_cd)
    assert (rows[2] == -1).all() and not eos[2] and (cd[2] == 0).all()
