"""Serving engine end-to-end: the paper's core claim at system level —
every COMPLETED generation is syntactically valid; partial outputs stay
in L_p(G) at every step."""
import jax
import pytest

from repro.core.decoding import DecodeConfig
from repro.core.parser import IncrementalParser
from repro.serving.engine import Engine, Request


@pytest.fixture(scope="module")
def demo_engine(tokenizer):
    from repro.configs import get_config
    from repro.models.model import build_model
    from tests.conftest import _BUNDLES

    # reuse session-level grammar bundles via the factory fixture pattern
    from repro.core.grammars import load_grammar
    from repro.core.mask_store import build_mask_store
    bundles = {}
    for name in ("json", "calc"):
        g, tab = load_grammar(name)
        bundles[name] = (g, tab, build_mask_store(g, tokenizer))
    cfg = get_config("syncode-demo")
    from dataclasses import replace
    cfg = replace(cfg, vocab_size=tokenizer.vocab_size, num_layers=2,
                  d_model=128, d_ff=256, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return Engine(model, params, tokenizer, bundles, max_len=200), bundles


def test_constrained_outputs_always_valid(demo_engine):
    engine, bundles = demo_engine
    reqs = [Request(rid=i, prompt=b"say:", grammar="json",
                    max_new_tokens=40,
                    decode=DecodeConfig(method="sample", temperature=1.0),
                    seed=i) for i in range(4)]
    states, stats = engine.generate(reqs)
    g, tab, _ = bundles["json"]
    p = IncrementalParser(g, tab)
    for st in states:
        assert st.finish_reason in ("eos", "length", "max_len")
        if st.finish_reason == "eos":
            assert p.recognize(st.generated), st.generated
        else:
            # partial outputs must be in L_p(G): partial_parse succeeds
            p2 = IncrementalParser(g, tab)
            p2.partial_parse(st.generated)   # raises if not


def test_unconstrained_random_model_breaks_grammar(demo_engine):
    """Sanity: without the mask, a random model essentially never emits
    valid JSON (the paper's standard-generation row)."""
    engine, bundles = demo_engine
    reqs = [Request(rid=i, prompt=b"say:", grammar=None, max_new_tokens=30,
                    decode=DecodeConfig(method="sample", temperature=1.0),
                    seed=100 + i) for i in range(3)]
    states, _ = engine.generate(reqs)
    g, tab, _ = bundles["json"]
    p = IncrementalParser(g, tab)
    assert sum(p.recognize(st.generated) for st in states) == 0


def test_opportunistic_masking_same_guarantees(demo_engine, tokenizer):
    engine, bundles = demo_engine
    engine.opportunistic = True
    try:
        reqs = [Request(rid=i, prompt=b"say:", grammar="calc",
                        max_new_tokens=30,
                        decode=DecodeConfig(method="sample",
                                            temperature=1.0),
                        seed=i) for i in range(3)]
        states, stats = engine.generate(reqs)
        g, tab, _ = bundles["calc"]
        p = IncrementalParser(g, tab)
        for st in states:
            if st.finish_reason == "eos":
                assert p.recognize(st.generated)
        # the fast path must actually fire sometimes
        assert stats.opportunistic_hits + stats.mask_computations == \
            stats.tokens
    finally:
        engine.opportunistic = False


def test_greedy_deterministic(demo_engine):
    engine, bundles = demo_engine
    out = []
    for _ in range(2):
        reqs = [Request(rid=0, prompt=b"x:", grammar="calc",
                        max_new_tokens=20,
                        decode=DecodeConfig(method="greedy"), seed=0)]
        states, _ = engine.generate(reqs)
        out.append(states[0].generated)
    assert out[0] == out[1]
