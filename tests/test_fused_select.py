"""Fused mask+filter+sample parity: the Pallas kernel, the jnp
reference, the precomputed-Gumbel-noise variant, and the legacy
two-call pipeline (apply mask, then select_batch) must all pick the
BIT-IDENTICAL token for identical inputs — that identity is what lets
the engine swap the fused call in without changing a single generated
token (ISSUE 9 acceptance: token-for-token identity in every mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade gracefully: only @given tests skip
    from tests._hypothesis_stub import given, settings, st

from repro.core.decoding import select_batch
from repro.kernels.fused_select.kernel import fused_select
from repro.kernels.fused_select.ref import fused_select_ref, gumbel_noise
from repro.kernels.masked_logits.ref import masked_logits_ref


def _step_inputs(rng, B, V, R, A):
    """One random fused-select problem: store rows, row ids with -1 pad,
    residue overlay, per-slot flags and decode configs."""
    store = rng.integers(0, 2 ** 32, size=(R, V // 32), dtype=np.uint32)
    rows = rng.integers(-1, R, size=(B, A)).astype(np.int32)
    cd = rng.integers(0, 2 ** 32, size=(B, V // 32), dtype=np.uint32)
    # zero some residue rows: the common no-residue case must be covered
    cd[rng.random(B) < 0.5] = 0
    logits = rng.normal(size=(B, V)).astype(np.float32)
    eos = rng.integers(0, 2, size=(B,)).astype(bool)
    constrained = rng.integers(0, 2, size=(B,)).astype(bool)
    greedy = rng.integers(0, 2, size=(B,)).astype(bool)
    temp = rng.uniform(0.4, 1.6, size=(B,)).astype(np.float32)
    top_k = rng.integers(0, 12, size=(B,)).astype(np.int32)
    top_p = rng.uniform(0.5, 1.2, size=(B,)).astype(np.float32)
    keys = rng.integers(0, 2 ** 32, size=(B, 2), dtype=np.uint32)
    return (jnp.asarray(logits), jnp.asarray(store), jnp.asarray(rows),
            jnp.asarray(cd), jnp.asarray(eos), jnp.asarray(constrained),
            jnp.asarray(greedy), jnp.asarray(temp), jnp.asarray(top_k),
            jnp.asarray(top_p), jnp.asarray(keys))


@pytest.mark.parametrize("B,V,R,A", [
    (1, 512, 32, 4),
    (4, 2048, 300, 12),
    (3, 1024, 64, 48),
])
def test_all_variants_bit_identical(B, V, R, A):
    (logits, store, rows, cd, eos, cons, greedy, temp, top_k, top_p,
     keys) = _step_inputs(np.random.default_rng(B * V + A), B, V, R, A)
    # legacy two-call pipeline: mask, then the pre-fusion selector
    masked_legacy = masked_logits_ref(logits, store, rows, eos,
                                     constrained=cons, cd=cd)
    ids_legacy = select_batch(masked_legacy, keys, greedy, temp, top_k,
                              top_p)
    # fused reference, keys variant (same categorical streams)
    ids_rk, masked_rk = fused_select_ref(logits, store, rows, cd, eos,
                                         cons, greedy, temp, top_k, top_p,
                                         keys=keys)
    # fused reference, precomputed-noise variant
    noise = gumbel_noise(keys, V)
    ids_rn, masked_rn = fused_select_ref(logits, store, rows, cd, eos,
                                         cons, greedy, temp, top_k, top_p,
                                         noise=noise)
    # Pallas kernel, noise variant (interpret=True executes on CPU)
    ids_k, masked_k = fused_select(logits, store, rows, cd, eos, cons,
                                   greedy, temp, top_k, top_p, noise,
                                   mode="sample", interpret=True)
    np.testing.assert_array_equal(np.asarray(ids_legacy),
                                  np.asarray(ids_rk))
    np.testing.assert_array_equal(np.asarray(ids_rk), np.asarray(ids_rn))
    np.testing.assert_array_equal(np.asarray(ids_rn), np.asarray(ids_k))
    for m in (masked_rk, masked_rn, masked_k):
        np.testing.assert_array_equal(np.asarray(masked_legacy),
                                      np.asarray(m))


def test_greedy_variant_matches_argmax():
    """The all-greedy host-static variant (no filter, no PRNG) must
    equal argmax over the masked logits — and the sample variant with
    greedy flags all-True must agree with it."""
    (logits, store, rows, cd, eos, cons, _, temp, top_k, top_p,
     keys) = _step_inputs(np.random.default_rng(3), 4, 1024, 80, 8)
    ones = jnp.ones((4,), bool)
    ids_g, masked_g = fused_select(
        logits, store, rows, cd, eos, cons, ones,
        jnp.ones((4,), jnp.float32), jnp.zeros((4,), jnp.int32),
        jnp.ones((4,), jnp.float32), jnp.zeros(logits.shape, jnp.float32),
        mode="greedy", interpret=True)
    ref = masked_logits_ref(logits, store, rows, eos, constrained=cons,
                            cd=cd)
    np.testing.assert_array_equal(np.asarray(ids_g),
                                  np.argmax(np.asarray(ref), axis=-1))
    np.testing.assert_array_equal(np.asarray(masked_g), np.asarray(ref))
    ids_s, _ = fused_select_ref(logits, store, rows, cd, eos, cons, ones,
                                temp, top_k, top_p,
                                noise=gumbel_noise(keys, 1024))
    np.testing.assert_array_equal(np.asarray(ids_g), np.asarray(ids_s))


def test_none_cd_means_no_overlay():
    """cd=None through the ref equals an explicit all-zero overlay."""
    (logits, store, rows, _, eos, cons, greedy, temp, top_k, top_p,
     keys) = _step_inputs(np.random.default_rng(9), 3, 512, 40, 6)
    zeros = jnp.zeros((3, 512 // 32), jnp.uint32)
    a = fused_select_ref(logits, store, rows, None, eos, cons, greedy,
                         temp, top_k, top_p, keys=keys)
    b = fused_select_ref(logits, store, rows, zeros, eos, cons, greedy,
                         temp, top_k, top_p, keys=keys)
    np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
    np.testing.assert_array_equal(np.asarray(a[1]), np.asarray(b[1]))


def test_span_form_matches_batch():
    from repro.kernels.fused_select.ops import (fused_mask_select,
                                                fused_mask_select_span)
    B, S, V, R, A = 2, 3, 512, 40, 6
    (logits, store, rows, cd, eos, cons, greedy, temp, top_k, top_p,
     keys) = _step_inputs(np.random.default_rng(17), B * S, V, R, A)
    ids_flat, masked_flat = fused_mask_select(
        logits, store, rows, cd, eos, cons,
        jnp.repeat(greedy[:B], S), jnp.repeat(temp[:B], S),
        jnp.repeat(top_k[:B], S), jnp.repeat(top_p[:B], S), keys=keys)
    ids_span, masked_span = fused_mask_select_span(
        logits.reshape(B, S, V), store, rows.reshape(B, S, A),
        cd.reshape(B, S, -1), eos.reshape(B, S), cons.reshape(B, S),
        greedy[:B], temp[:B], top_k[:B], top_p[:B],
        keys=keys.reshape(B, S, 2))
    np.testing.assert_array_equal(np.asarray(ids_flat).reshape(B, S),
                                  np.asarray(ids_span))
    np.testing.assert_array_equal(
        np.asarray(masked_flat).reshape(B, S, V), np.asarray(masked_span))


@settings(max_examples=15, deadline=None)
@given(B=st.integers(1, 4), A=st.integers(1, 16),
       seed=st.integers(0, 2 ** 16))
def test_fused_select_property(B, A, seed):
    """Kernel vs keys-reference under random shapes/configs — the
    strongest form: two different samplers (categorical vs
    argmax+noise), two different executors (XLA vs Pallas interpret),
    one answer."""
    V, R = 512, 40
    (logits, store, rows, cd, eos, cons, greedy, temp, top_k, top_p,
     keys) = _step_inputs(np.random.default_rng(seed), B, V, R, A)
    ids_ref, masked_ref = fused_select_ref(logits, store, rows, cd, eos,
                                           cons, greedy, temp, top_k,
                                           top_p, keys=keys)
    ids_k, masked_k = fused_select(logits, store, rows, cd, eos, cons,
                                   greedy, temp, top_k, top_p,
                                   gumbel_noise(keys, V),
                                   mode="sample", interpret=True)
    np.testing.assert_array_equal(np.asarray(ids_ref), np.asarray(ids_k))
    np.testing.assert_array_equal(np.asarray(masked_ref),
                                  np.asarray(masked_k))
