"""grammar_mask vs grammar_strict — the two mask approximation families
(docs/grammars.md), locked in by a differential:

  * strict ⊆ mask, BITWISE, for every row of every builtin store and at
    every step of sampled generations;
  * strict rows match a naive terminal-boundary-aligned oracle (the
    strict analogue of the paper's Def. 10 dmatch): a token survives
    only if its walk stays inside the current terminal, or splits
    exactly once at a final state with the rest walking live inside the
    single lookahead terminal — no overshoot into arbitrary bytes;
  * grammar_mask NEVER bans a ground-truth token of a valid program at
    any cut (the paper's soundness claim, here for python_mini with
    CPython `ast` as the external judge).
"""
import ast

import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade gracefully: only @given tests skip
    from tests._hypothesis_stub import given, settings, st

from repro.core.constrain import GrammarConstraint
from repro.core.grammars import BUILTIN
from repro.core.sampling import GrammarSampler
from repro.core.tokenizer import EOS_ID


# ------------- strict oracle (slow, obviously terminal-aligned) ---------

def strict_oracle(grammar, terminal, q, token: bytes, next_terminal=None):
    """A token is strict-allowed iff (a) its whole walk from q stays
    live inside the current terminal, or (b) with a lookahead τ': some
    prefix lands in F_τ and the ENTIRE rest walks live inside τ' from
    its start (empty rest allowed). Dead states are absorbing, so
    "ends live" == "every step stayed live"."""
    dfa = grammar.terminals[terminal].dfa
    s = q
    states = [s]
    for b in token:
        s = int(dfa.trans[s, b])
        states.append(s)
    if dfa.live[s]:
        return True
    if next_terminal is None:
        return False
    d2 = grammar.terminals[next_terminal].dfa
    for i in range(len(token) + 1):
        if not dfa.finals[states[i]]:
            continue
        s2 = d2.start
        for b in token[i:]:
            s2 = int(d2.trans[s2, b])
        if d2.live[s2]:
            return True
    return False


@pytest.mark.parametrize("name", ["calc", "python_mini"])
def test_strict_rows_match_oracle(name, grammar_bundle, tokenizer):
    g, tab, store, gc = grammar_bundle(name)
    rng = np.random.default_rng(1)
    toks = tokenizer.token_bytes()
    token_ids = rng.choice(np.arange(3, tokenizer.vocab_size), size=50,
                           replace=False)
    terms = g.terminal_names
    for t1 in terms[:: max(1, len(terms) // 12)]:
        dfa = g.terminals[t1].dfa
        qs = [q for q in range(dfa.num_states) if dfa.live[q]][:4]
        for q in qs:
            row0 = store.unpack(store.packed[store.row_m0(t1, q,
                                                          strict=True)])
            for tid in token_ids[:20]:
                want = strict_oracle(g, t1, q, toks[tid])
                assert bool(row0[tid]) == want, (t1, q, toks[tid], "M0s")
            for t2 in (terms[0], terms[-1]):
                row1 = store.unpack(
                    store.packed[store.row_m1(t1, q, t2, strict=True)])
                for tid in token_ids[20:40]:
                    want = strict_oracle(g, t1, q, toks[tid], t2)
                    assert bool(row1[tid]) == want, (t1, q, toks[tid], t2)


# ----------------------- strict ⊆ mask, bitwise -------------------------

@pytest.mark.parametrize("name", BUILTIN)
def test_strict_subset_of_mask_every_row(name, grammar_bundle):
    """Whole-store bitwise containment: no strict row may set a bit its
    mask-family twin clears."""
    _, _, store, _ = grammar_bundle(name)
    R = store.strict_offset
    mask_half = store.packed[:R]
    strict_half = store.packed[R:]
    viol = strict_half & ~mask_half
    assert not viol.any(), f"{name}: strict row allows a mask-banned token"


@pytest.mark.parametrize("name", ["json", "calc", "python_mini"])
def test_strict_subset_per_step(name, grammar_bundle, tokenizer):
    """Differential at real generation cuts: both constraints walk the
    same sampled program; at every token boundary the strict mask must
    be a subset of the grammar_mask mask."""
    g, tab, store, _ = grammar_bundle(name)
    gm = GrammarConstraint(g, tab, store, tokenizer, mode="grammar_mask")
    gs_ = GrammarConstraint(g, tab, store, tokenizer,
                            mode="grammar_strict")
    sampler = GrammarSampler(g, seed=23)
    checked = 0
    for _ in range(5):
        s = sampler.sample(16, max_bytes=200)
        prefix = b""
        for tid in tokenizer.encode(s):
            m = gm.token_mask(prefix)
            ms = gs_.token_mask(prefix)
            extra = ms & ~m
            assert not extra.any(), (
                f"{name}: strict allows {np.nonzero(extra)[0][:5]} at "
                f"{prefix!r} that grammar_mask bans")
            checked += 1
            prefix += tokenizer.id_to_bytes[tid]
    assert checked > 20


def test_mode_selects_row_family(grammar_bundle, tokenizer):
    """Under the context split the two families SHARE the
    context-independent rows (strict-half M0 rows by construction), so
    the strict mode's rows all live in the strict half, while the mask
    mode mixes its own family rows with stride-aligned CI rows."""
    g, tab, store, _ = grammar_bundle("calc")
    gm = GrammarConstraint(g, tab, store, tokenizer, mode="grammar_mask")
    gs_ = GrammarConstraint(g, tab, store, tokenizer,
                            mode="grammar_strict")
    R = store.strict_offset
    smm = gm.step_rows(b"1+")
    sms = gs_.step_rows(b"1+")
    rm, rs = smm.rows, sms.rows
    assert (rs[rs >= 0] >= R).all()
    # mask-family rows in the strict half can only be the shared CI
    # rows — a state's strict M0, hence stride-aligned
    shared = rm[(rm >= 0) & (rm >= R)]
    assert ((shared - R) % store.row_stride == 0).all()
    # and the full packed unions still order strict subset-of mask
    um = gm.union_packed(smm)
    us = gs_.union_packed(sms)
    assert not (us & ~um).any()


def test_unknown_mode_rejected(grammar_bundle, tokenizer):
    g, tab, store, _ = grammar_bundle("calc")
    with pytest.raises(ValueError, match="grammar mode"):
        GrammarConstraint(g, tab, store, tokenizer, mode="strict")


# ------------- mask soundness with an external judge (ast) --------------

def test_mask_never_bans_valid_python_tokens(grammar_bundle, tokenizer):
    """Ground truth from the sampler, validated by CPython itself: at
    every cut of every ast-clean program, grammar_mask must keep the
    actual next token (Thm. 1 soundness on a real language)."""
    g, tab, store, gc = grammar_bundle("python_mini")
    sampler = GrammarSampler(g, seed=31)
    programs = 0
    for _ in range(6):
        s = sampler.sample(16, max_bytes=240)
        ast.parse(s.decode("ascii"))        # external ground truth
        programs += 1
        prefix = b""
        for tid in tokenizer.encode(s):
            assert gc.token_mask(prefix)[tid], (
                f"mask bans valid token {tokenizer.id_to_bytes[tid]!r} "
                f"after {prefix!r}")
            prefix += tokenizer.id_to_bytes[tid]
        assert gc.token_mask(s)[EOS_ID]
    assert programs == 6


@pytest.mark.parametrize("name", ["calc", "python_mini"])
def test_strict_subset_at_midtoken_cuts(name, grammar_bundle, tokenizer):
    """Deterministic mid-token-cut differential (runs even without
    hypothesis): random BYTE cuts, not token boundaries — the
    adversarial case for the dual suffix tables."""
    g, tab, store, _ = grammar_bundle(name)
    gm = GrammarConstraint(g, tab, store, tokenizer, mode="grammar_mask")
    gs_ = GrammarConstraint(g, tab, store, tokenizer,
                            mode="grammar_strict")
    rng = np.random.default_rng(7)
    sampler = GrammarSampler(g, seed=7)
    for _ in range(4):
        prog = sampler.sample(14, max_bytes=200)
        for cut in rng.integers(0, len(prog) + 1, size=12):
            prefix = prog[:cut]
            m = gm.token_mask(prefix)
            ms = gs_.token_mask(prefix)
            assert not (ms & ~m).any(), (name, int(cut), prefix)


# --------------------- hypothesis differential fuzz ---------------------

@settings(deadline=None, max_examples=20)
@given(st.sampled_from(["calc", "json", "python_mini"]),
       st.integers(0, 10 ** 6), st.data())
def test_fuzz_strict_subset_at_random_cuts(name, seed, data):
    from repro.core.grammars import load_grammar
    from repro.core.mask_store import build_mask_store
    from repro.core.tokenizer import ByteTokenizer
    from tests.conftest import _BUNDLES
    # reuse the session store if the fixture already built it (hypothesis
    # fns cannot take fixtures); else build once into the shared dict
    if name not in _BUNDLES:
        tok = ByteTokenizer(1024)
        g, tab = load_grammar(name)
        store = build_mask_store(g, tok)
        _BUNDLES[name] = (g, tab, store,
                          GrammarConstraint(g, tab, store, tok))
    g, tab, store, gc = _BUNDLES[name]
    tok = gc.tokenizer
    gm = GrammarConstraint(g, tab, store, tok, mode="grammar_mask")
    gs_ = GrammarConstraint(g, tab, store, tok, mode="grammar_strict")
    prog = GrammarSampler(g, seed=seed).sample(14, max_bytes=200)
    cut = data.draw(st.integers(0, len(prog)))
    # cuts mid-token are exactly the adversarial case for boundary logic
    prefix = prog[:cut]
    try:
        m = gm.token_mask(prefix)
        ms = gs_.token_mask(prefix)
    except Exception:
        # a mid-byte cut may be unparseable for BOTH; that is fine, but
        # it must be unparseable consistently
        with pytest.raises(Exception):
            gm.token_mask(prefix)
        return
    assert not (ms & ~m).any(), (name, seed, cut, prefix)
