"""Paged KV allocator: refcount/free-list/prefix-cache invariants under
random op sequences, checked against a dense shadow cache.

The fuzz harness drives the PUBLIC allocator API (admit / prepare_write /
note_fill / fork / release) exactly the way the engine does, mirrors
every directed device action (page copies, token writes) into a fake
numpy "pool", and asserts after every op that

  * refcounts equal the observed references (tables + prefix cache),
  * free pages are unreferenced, no double frees, cold pages cache-only
    (`PagedAllocator.check_invariants`),
  * reconstructing each live slot through its page table yields exactly
    the tokens the shadow says it holds — including slots whose prefix
    pages are SHARED with other slots or the cache, and slots that
    forked + diverged through copy-on-write.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade gracefully: only @given tests skip
    from tests._hypothesis_stub import given, settings, st

from repro.serving.kvpool import PagedAllocator, PoolExhausted

PS = 4          # page size
PAGES = 24
SLOTS = 4
MAXP = 8        # max pages per slot -> max seq 32


class Sim:
    """Engine stand-in: drives the allocator and mirrors device state."""

    def __init__(self, pages=PAGES, ps=PS, slots=SLOTS, maxp=MAXP):
        self.a = PagedAllocator(pages, ps, slots, maxp)
        self.ps = ps
        self.pool = np.full((pages, ps), -1, np.int64)   # fake device pool
        self.shadow = {}                                 # b -> list[tokens]
        self.frontier = {}                               # b -> written len

    # -- engine-protocol ops ------------------------------------------

    def admit(self, b, ids):
        plan = self.a.admit(b, ids)
        self.shadow[b] = list(ids)
        self.frontier[b] = plan.write_from
        # chunked prefill, all at once: write the unmatched tail
        self.write(b, plan.write_from, len(ids), ids[plan.write_from:])
        self.a.note_fill(b, len(ids))
        return plan

    def write(self, b, start, end, values):
        for src, dst in self.a.prepare_write(b, start, end):
            self.pool[dst] = self.pool[src]              # device COW copy
        t = self.a.tables[b]
        for i, v in zip(range(start, end), values):
            self.pool[t[i // self.ps], i % self.ps] = v
        self.frontier[b] = max(self.frontier[b], end)

    def append(self, b, values):
        n = len(self.shadow[b])
        self.write(b, n, n + len(values), values)
        self.shadow[b].extend(values)
        self.a.note_fill(b, len(self.shadow[b]))

    def fork(self, src, dst):
        self.a.fork(src, dst)
        self.shadow[dst] = list(self.shadow[src])
        self.frontier[dst] = self.frontier[src]

    def release(self, b):
        self.a.release(b)
        self.shadow.pop(b, None)
        self.frontier.pop(b, None)

    # -- checks --------------------------------------------------------

    def check(self):
        self.a.check_invariants()
        for b, want in self.shadow.items():
            t = self.a.tables[b]
            got = [int(self.pool[t[i // self.ps], i % self.ps])
                   for i in range(len(want))]
            assert got == list(want), (b, got, want)


# ---------------------------- directed tests -------------------------------

def test_prefix_sharing_attaches_full_pages():
    s = Sim()
    ids = list(range(100, 100 + 11))                 # 2 full pages + tail
    s.admit(0, ids)
    s.check()
    p1 = s.admit(1, ids)
    assert p1.matched_len == 8 and p1.feed_from == 8
    assert s.a.tables[0][:2] == s.a.tables[1][:2]    # physical sharing
    assert s.a.tables[0][2] != s.a.tables[1][2]      # private tails
    s.check()
    assert s.a.prefix_hit_rate > 0


def test_release_turns_shared_pages_cold_and_rehit():
    s = Sim()
    ids = list(range(1, 13))                         # 12 tokens = 3 full pages
    s.admit(0, ids)
    s.release(0)
    s.check()
    assert s.a.cold_pages == 3                       # all registered, cold
    plan = s.admit(1, ids)                           # warm rehit from cold
    assert plan.matched_len == 12 and plan.feed_from == 11
    assert s.a.cold_pages == 0                       # re-attached = warm
    s.check()


def test_full_page_aligned_prompt_matches_to_last_token():
    s = Sim()
    ids = list(range(1, 9))                          # exactly two full pages
    s.admit(0, ids)
    s.release(0)
    plan = s.admit(1, ids)
    # whole prompt attached; engine re-feeds only the last token,
    # read-only (feed_from = plen - 1, write_from = plen)
    assert plan.matched_len == 8
    assert plan.feed_from == 7 and plan.write_from == 8
    s.check()


def test_fork_then_append_cow():
    s = Sim()
    ids = list(range(50, 60))
    s.admit(0, ids)
    s.fork(0, 1)
    s.check()
    assert s.a.tables[0] == s.a.tables[1]
    s.append(1, [7, 8, 9])                           # diverge via COW
    s.append(0, [1, 2, 3])
    s.check()                                        # both exact
    assert s.a.tables[0][-1] != s.a.tables[1][-1]
    assert s.a.cow_copies >= 1


def test_eviction_under_pressure_and_exhaustion():
    s = Sim(pages=6, slots=3, maxp=6)
    s.admit(0, list(range(10)))                      # 3 pages
    s.release(0)                                     # 2 cached cold
    assert s.a.cold_pages == 2
    s.admit(1, list(range(100, 118)))                # needs 5 pages
    assert s.a.evictions >= 1                        # ate the cold cache
    s.check()
    with pytest.raises(PoolExhausted):
        s.admit(2, list(range(200, 212)))            # nothing left
    s.check()                                        # failed admit rolled back
    assert s.a.tables[2] == []


def test_waiting_and_writer_orphan_claim():
    a = PagedAllocator(16, 4, 3, 4)
    ids = list(range(9))
    a.admit(0, ids)                                  # writer of 2 pages
    plan1 = a.admit(1, ids)
    assert plan1.matched_len == 8
    assert a.ready(1) is None                        # writer still filling
    a.note_fill(0, 4)                                # one page done
    assert a.ready(1) is None
    a.release(0)                                     # orphan page 2nd page
    ff, wf = a.ready(1)                              # claim: refill from 4
    assert wf == 4 and ff == 4
    a.prepare_write(1, 4, 9)
    a.note_fill(1, 9)
    assert a.ready(1) == (4, 4)
    a.check_invariants()


def test_fork_never_claims_writer_rights():
    """ready() on a forked slot must not claim the source's pages — a
    claim would let prepare_write skip the COW and clobber pages the
    source still reads."""
    s = Sim()
    ids = list(range(50, 60))                        # 2 full + partial tail
    s.admit(0, ids)
    s.fork(0, 1)
    assert s.a.ready(1) is not None                  # no waiting, and...
    tail = s.a.tables[0][-1]
    assert s.a.writer.get(tail) != 1                 # ...no claim happened
    s.append(1, [1, 2])                              # must COW, not clobber
    s.check()
    assert s.a.tables[0][-1] != s.a.tables[1][-1]


def test_orphan_claim_stops_at_live_writer():
    """Claiming an orphaned prefix run must not steal pages a live
    writer is still filling."""
    a = PagedAllocator(24, 4, 4, 6)
    ids = list(range(17))                            # 4 full pages + tail
    a.admit(0, ids)
    a.note_fill(0, 8)                                # pages 0,1 full
    p2, p3 = a.tables[0][2], a.tables[0][3]
    a.admit(1, ids)                                  # attaches 4 full pages
    a.release(0)                                     # orphans pages 2,3
    a.writer[p3] = 2                                 # simulate live writer
    ff, wf = a.ready(1)                              # claim page 2 only
    assert wf == 8 and a.writer[p2] == 1 and a.writer[p3] == 2


def test_pages_in_use_accounting():
    s = Sim()
    assert s.a.pages_in_use == 0
    s.admit(0, list(range(6)))
    assert s.a.pages_in_use == 2
    s.append(0, list(range(6)))                      # grow to 12 tokens
    assert s.a.pages_in_use == 3
    assert s.a.peak_in_use == 3
    s.release(0)
    assert s.a.pages_in_use == 1                     # one cached cold page
    s.check()


# ----------------------------- fuzz harness --------------------------------

_token = st.integers(min_value=0, max_value=30)      # small alphabet: real
                                                     # cross-slot collisions


@st.composite
def _op(draw):
    kind = draw(st.sampled_from(
        ["admit", "append", "fork", "release", "release", "admit"]))
    return (kind, draw(st.integers(0, SLOTS - 1)),
            draw(st.lists(_token, min_size=1, max_size=14)))


@settings(max_examples=60, deadline=None)
@given(st.lists(_op(), min_size=1, max_size=40))
def test_fuzz_alloc_append_fork_free_vs_shadow(ops):
    s = Sim()
    for kind, b, toks in ops:
        try:
            if kind == "admit":
                if b in s.shadow:
                    s.release(b)
                s.admit(b, toks)
            elif kind == "append" and b in s.shadow:
                room = MAXP * PS - len(s.shadow[b])
                if room > 0:
                    s.append(b, toks[:room])
            elif kind == "fork" and b in s.shadow:
                dst = (b + 1) % SLOTS
                if dst not in s.shadow:
                    s.fork(b, dst)
            elif kind == "release" and b in s.shadow:
                s.release(b)
        except PoolExhausted:
            pass                                     # legal under pressure
        s.check()
    for b in list(s.shadow):
        s.release(b)
        s.check()
    # after releasing everything, only the prefix cache may hold pages
    assert s.a.pages_in_use == s.a.cold_pages


# ------------------- prepare_write atomicity (kv_oom) ----------------------

def _leaked_pages(a):
    """Pages still referenced that are NOT legitimate cache-cold holds
    (a leak candidate: held but unreachable through any table/cache)."""
    return [p for p in range(a.P)
            if a.refcount[p] > 0 and not
            (a.refcount[p] == 1 and p in a._rev and a.full[p])]


def test_prepare_write_exhaustion_is_atomic():
    """A multi-page feed that cannot be fully reserved must acquire
    NOTHING: the table, refcounts and free list are untouched, so a
    caller may keep the slot alive (or release it later) without
    leaking the grown head or losing pending COW copies."""
    a = PagedAllocator(num_pages=4, page_size=4, slots=2,
                       max_pages_per_slot=8)
    a.admit(0, list(range(10)))                      # 3 pages
    a.note_fill(0, 10)
    before_table = list(a.tables[0])
    before_ref = list(a.refcount)
    before_free = list(a.free)
    # needs 3 more pages (to cover 24 tokens) but only 1 is allocatable
    with pytest.raises(PoolExhausted):
        a.prepare_write(0, 10, 24)
    assert a.tables[0] == before_table
    assert a.refcount == before_ref
    assert list(a.free) == before_free
    a.check_invariants()
    # the slot is still fully usable afterwards
    assert a.prepare_write(0, 10, 14) == []
    a.release(0)
    assert not _leaked_pages(a)


def test_prepare_write_exhaustion_with_cow_is_atomic():
    """Same, when the failing feed also crosses SHARED pages: no COW
    swap may happen unless the whole reservation fits."""
    a = PagedAllocator(num_pages=4, page_size=4, slots=3,
                       max_pages_per_slot=8)
    a.admit(0, list(range(8)))                       # 2 full prompt pages
    a.note_fill(0, 8)
    a.fork(0, 1)                                     # all pages shared
    before_table = list(a.tables[1])
    before_ref = list(a.refcount)
    # writing [6, 16) needs 1 COW (mid-page 1) + 2 growth pages; only
    # 2 pages are allocatable -> must refuse without swapping anything
    with pytest.raises(PoolExhausted):
        a.prepare_write(1, 6, 16)
    assert a.tables[1] == before_table
    assert a.refcount == before_ref
    a.check_invariants()
    a.release(0)
    a.release(1)
    assert not _leaked_pages(a)


def test_prepare_write_atomic_when_table_longer_than_range():
    """The atomicity precheck must clamp negative growth: a COW-only
    write whose range ends INSIDE an already-longer table (grow < 0)
    must not let the negative headroom offset the COW count — that
    would pass the reservation check and fail mid-COW-loop, mutating
    the table."""
    a = PagedAllocator(num_pages=7, page_size=4, slots=3,
                       max_pages_per_slot=8)
    a.admit(0, list(range(12)))                      # 3 full prompt pages
    a.note_fill(0, 12)
    a.fork(0, 1)
    a.prepare_write(1, 12, 24)                       # grow slot 1 to 6 pages
    assert a.available() == 1
    before_table = list(a.tables[1])
    before_ref = list(a.refcount)
    # [2, 8) covers 2 shared pages -> 2 COW allocs; need=2 < len(t)=6,
    # so unclamped grow would be -4 and the check would wrongly pass
    with pytest.raises(PoolExhausted):
        a.prepare_write(1, 2, 8)
    assert a.tables[1] == before_table
    assert a.refcount == before_ref
    a.check_invariants()
    a.release(0)
    a.release(1)
    assert not _leaked_pages(a)


@settings(max_examples=60, deadline=None)
@given(ops=st.lists(st.tuples(st.integers(0, SLOTS - 1),
                          st.lists(_token, min_size=1, max_size=12)),
                min_size=1, max_size=30),
       grow=st.integers(1, 40))
def test_forced_exhaustion_returns_pool_to_baseline(ops, grow):
    """Regression (kv_oom audit): drive admits/appends until the pool
    throws PoolExhausted, release everything, and require every page to
    return to free-or-cache-cold — no page may stay referenced by a
    dead slot."""
    s = Sim(pages=10, ps=PS, slots=SLOTS, maxp=MAXP)
    saw_oom = False
    for b, toks in ops:
        try:
            if b in s.shadow:
                room = MAXP * PS - len(s.shadow[b])
                s.append(b, (toks * 4)[:max(1, min(grow, room))])
            else:
                s.admit(b, toks)
        except PoolExhausted:
            saw_oom = True
            # engine behavior: the request finishes kv_oom -> release
            if b in s.shadow:
                s.release(b)
            else:
                # failed admit already rolled itself back
                assert not s.a.tables[b]
        s.check()
    for b in list(s.shadow):
        s.release(b)
    s.check()
    assert s.a.pages_in_use == s.a.cold_pages
    assert not _leaked_pages(s.a)
    if saw_oom:
        # at least one exhaustion was exercised on this example
        assert s.a.P == 10
