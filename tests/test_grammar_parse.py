"""Grammar frontend, lexer, LR tables, incremental parser."""
import json as pyjson
import random

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade gracefully: only @given tests skip
    from tests._hypothesis_stub import given, settings, st

from repro.core.grammars import BUILTIN, load_grammar
from repro.core.lexer import LexError, lex_partial
from repro.core.parser import IncrementalParser, ParseError
from repro.core.sampling import GrammarSampler


@pytest.mark.parametrize("name", BUILTIN)
def test_grammar_compiles(name):
    g, tab = load_grammar(name)
    assert tab.num_states > 3
    assert g.total_dfa_states > 0


# ---------------- JSON vs Python's json module -------------------------

json_values = st.recursive(
    st.none() | st.booleans() | st.integers(-10**6, 10**6)
    | st.floats(allow_nan=False, allow_infinity=False, width=32)
    | st.text(alphabet=st.characters(min_codepoint=32, max_codepoint=126,
                                     exclude_characters='"\\'), max_size=8),
    lambda ch: st.lists(ch, max_size=4)
    | st.dictionaries(st.text(alphabet="abcdef_", max_size=6), ch,
                      max_size=4),
    max_leaves=10,
)


@settings(max_examples=150, deadline=None)
@given(v=json_values)
def test_json_recognizes_python_json(v, grammar_bundle):
    g, tab, _, _ = grammar_bundle("json")
    p = IncrementalParser(g, tab)
    s = pyjson.dumps(v)
    assert p.recognize(s.encode()), s


@pytest.mark.parametrize("bad", [
    b"{", b"[1,]", b'{"a" 1}', b'{"a": 1,,}', b"tru", b"[1 2]",
    b'{"a": 1} extra', b"'single'", b"{]",
])
def test_json_rejects_invalid(bad, grammar_bundle):
    g, tab, _, _ = grammar_bundle("json")
    p = IncrementalParser(g, tab)
    assert not p.recognize(bad)


# ---------------- sampled strings recognized, all grammars --------------

@pytest.mark.parametrize("name", BUILTIN)
def test_sampled_strings_recognized(name, grammar_bundle):
    g, tab, _, _ = grammar_bundle(name)
    p = IncrementalParser(g, tab)
    gs = GrammarSampler(g, seed=7)
    for _ in range(20):
        s = gs.sample(14, max_bytes=400)
        assert p.recognize(s), s


# ---------------- lexer remainder cases --------------------------------

def test_lexer_case2_unlexed_suffix(grammar_bundle):
    g, _, _, _ = grammar_bundle("calc")
    toks, rem = lex_partial(g, b"math_sqrt(2.")
    assert rem == b"2."
    assert [t.type for t in toks] == ["__MATH_SQRT", "__LPAR"]


def test_lexer_case1_complete_final_token(grammar_bundle):
    g, _, _, _ = grammar_bundle("calc")
    toks, rem = lex_partial(g, b"math_sqrt(23")
    assert rem == b""
    assert toks[-1].type == "INT" and toks[-1].value == b"23"


def test_lexer_dead_suffix_raises(grammar_bundle):
    g, _, _, _ = grammar_bundle("calc")
    with pytest.raises(LexError):
        lex_partial(g, b"1 @ 2")


def test_lexer_maximal_munch(grammar_bundle):
    g, _, _, _ = grammar_bundle("minilang")
    toks, rem = lex_partial(g, b"a<=b ")
    assert [t.type for t in toks if t.type != "WS"] == \
        ["NAME", "__LESSTHAN_EQUAL", "NAME"]
    # keyword vs identifier
    toks, _ = lex_partial(g, b"iffy ")
    assert toks[0].type == "NAME"
    toks, _ = lex_partial(g, b"if ")
    assert toks[0].type == "__IF"


# ---------------- incremental == from-scratch ---------------------------

@pytest.mark.parametrize("name", BUILTIN)
def test_incremental_matches_scratch(name, grammar_bundle):
    g, tab, _, _ = grammar_bundle(name)
    gs = GrammarSampler(g, seed=3)
    p = IncrementalParser(g, tab)
    rng = random.Random(0)
    for _ in range(10):
        s = gs.sample(12, max_bytes=200)
        # grow the string in random increments, as an LLM would
        i = 0
        while i < len(s):
            i = min(len(s), i + rng.randint(1, 4))
            inc = p.partial_parse(s[:i], incremental=True)
            p2 = IncrementalParser(g, tab)
            scratch = p2.partial_parse(s[:i], incremental=False)
            assert inc.remainder == scratch.remainder
            assert set(inc.accept_sequences) == set(scratch.accept_sequences)
            assert inc.eos_allowed == scratch.eos_allowed


def test_parse_error_on_garbage(grammar_bundle):
    g, tab, _, _ = grammar_bundle("json")
    p = IncrementalParser(g, tab)
    with pytest.raises((ParseError, LexError)):
        p.partial_parse(b'{"a": 1}}')


def test_eos_allowed_iff_complete(grammar_bundle):
    g, tab, _, _ = grammar_bundle("json")
    p = IncrementalParser(g, tab)
    assert p.partial_parse(b'{"a": 1}').eos_allowed
    assert p.partial_parse(b'{"a": 1} ').eos_allowed  # trailing ignored WS
    assert not p.partial_parse(b'{"a": 1').eos_allowed
    assert not p.partial_parse(b'').eos_allowed
