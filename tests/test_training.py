"""Training substrate: loss decreases on grammar data; optimizer math;
checkpoint roundtrip; data pipeline validity."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest


def test_train_loss_decreases(tokenizer, tmp_path):
    from dataclasses import replace
    from repro.configs import get_config
    from repro.core.grammars import load_grammar
    from repro.models.model import build_model
    from repro.training.data import GrammarDataPipeline
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_loop import train

    cfg = replace(get_config("syncode-demo"),
                  vocab_size=tokenizer.vocab_size, num_layers=2,
                  d_model=128, d_ff=256, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    g, _ = load_grammar("calc")
    data = iter(GrammarDataPipeline(g, tokenizer, seq_len=64, batch_size=4,
                                    seed=0))
    ck = str(tmp_path / "ck.msgpack")
    params, result = train(model, params, data, steps=30,
                           opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=5,
                                               total_steps=30),
                           log_every=5, checkpoint_path=ck, verbose=False)
    assert result.losses[-1] < result.losses[0] - 0.3, result.losses
    assert os.path.exists(ck)


def test_checkpoint_roundtrip(tmp_path):
    from repro.training.checkpoint import load_checkpoint, save_checkpoint
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.float32),
                  "d": jnp.zeros((), jnp.int32)}}
    path = str(tmp_path / "t.msgpack")
    save_checkpoint(path, tree, step=7, extra={"note": "x"})
    loaded, step, extra = load_checkpoint(path, tree)
    assert step == 7 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_optimizer_converges_quadratic():
    from repro.training.optimizer import (AdamWConfig, apply_updates,
                                          init_opt_state)
    params = {"w": jnp.array([3.0, -2.0])}
    cfg = AdamWConfig(lr=0.2, warmup_steps=0, total_steps=200,
                      weight_decay=0.0, clip_norm=10.0)
    state = init_opt_state(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, m = apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grammar_data_pipeline_is_valid_language(tokenizer, grammar_bundle):
    from repro.core.parser import IncrementalParser
    from repro.training.data import GrammarDataPipeline
    g, tab, _, _ = grammar_bundle("json")
    pipe = iter(GrammarDataPipeline(g, tokenizer, seq_len=48, batch_size=2,
                                    seed=3))
    batch = next(pipe)
    assert batch["tokens"].shape == (2, 48)
    assert batch["labels"].shape == (2, 48)
    # labels are tokens shifted by one
    np.testing.assert_array_equal(batch["tokens"][:, 1:],
                                  batch["labels"][:, :-1])
    # decoding the stream and splitting on EOS yields valid strings
    p = IncrementalParser(g, tab)
    ids = np.concatenate([batch["tokens"][0], batch["labels"][0][-1:]])
    text = b""
    segs = []
    for t in ids:
        if t == 1:  # EOS
            segs.append(text)
            text = b""
        else:
            text += tokenizer.id_to_bytes[int(t)]
    for s in segs[1:-1] if len(segs) > 2 else []:
        assert p.recognize(s), s
