"""Grammar-aware speculative decoding: soundness + exact equivalence.

The two system-level guarantees:
  * every speculative mechanism (jump-forward forced tokens, draft-verify
    accepted tokens) emits only tokens the exact parser oracle admits —
    partial outputs stay in L_p(G) at every step;
  * greedy speculative decoding is token-for-token IDENTICAL to the
    plain batched engine on every builtin grammar (forced tokens are the
    masked distribution's single support point; accepted drafts equal the
    selection the plain engine would have made).
"""
import jax
import numpy as np
import pytest

from repro.core.constrain import GrammarConstraint
from repro.core.decoding import DecodeConfig
from repro.core.grammars import BUILTIN, load_grammar
from repro.core.mask_store import build_mask_store
from repro.core.parser import IncrementalParser
from repro.core.sampling import GrammarSampler
from repro.serving.engine import Engine, Request
from repro.spec import (NGramProposer, SpecConfig, SuffixAutomatonProposer,
                        forced_literal, jump_forward, retokenize_aligned)


# --------------------------- proposers ---------------------------------

def test_suffix_automaton_proposes_repeated_continuation():
    p = SuffixAutomatonProposer()
    p.extend([5, 6, 7, 8, 9, 5, 6, 7])
    # longest earlier suffix is (5, 6, 7) ending at index 2 -> continue 8, 9
    assert p.match_len() == 3
    assert p.propose(2) == [8, 9]
    assert p.propose(4) == [8, 9, 5, 6]


def test_suffix_automaton_no_match_proposes_nothing():
    p = SuffixAutomatonProposer()
    p.extend([1, 2, 3, 4])
    assert p.propose(3) == []


def test_suffix_automaton_min_match_gates():
    p = SuffixAutomatonProposer(min_match=3)
    p.extend([1, 2, 9, 3, 2, 9])     # longest repeated suffix (2, 9): len 2
    assert p.propose(2) == []
    q = SuffixAutomatonProposer(min_match=2)
    q.extend([1, 2, 9, 3, 2, 9])
    assert q.propose(1) == [3]


def test_ngram_proposer_matches_sam_on_simple_loop():
    sam, ng = SuffixAutomatonProposer(), NGramProposer(max_n=4)
    seq = [3, 1, 4, 1, 5, 3, 1, 4]
    sam.extend(seq)
    ng.extend(seq)
    assert sam.propose(2) == ng.propose(2) == [1, 5]


# ----------------------- mask-store spec queries ------------------------

def test_popcount_and_sole_survivor_match_unpack(grammar_bundle):
    g, tab, store, gc = grammar_bundle("json")
    rng = np.random.default_rng(0)
    for _ in range(50):
        rows = rng.integers(-1, store.num_rows,
                            size=rng.integers(1, 8)).astype(np.int64)
        ref = np.zeros(store.tokenizer.vocab_size, bool)
        for r in rows:
            if r >= 0:
                ref |= store.unpack(store.packed[r])
        assert store.union_popcount(rows) == int(ref.sum())
        sole = store.sole_survivor(rows)
        if ref.sum() == 1:
            assert sole == int(np.argmax(ref))
        else:
            assert sole is None


def test_row_popcounts_lazy_table(grammar_bundle):
    g, tab, store, gc = grammar_bundle("calc")
    pc = store.row_popcounts()
    assert pc.shape == (store.num_rows,)
    for r in (0, store.num_rows // 2, store.num_rows - 1):
        assert pc[r] == int(store.unpack(store.packed[r]).sum())


def test_allowed_first_bytes_matches_token_scan(grammar_bundle):
    g, tab, store, gc = grammar_bundle("json")
    sm = gc.step_rows(b'{"a": ')
    union = store.union_rows(sm.rows)
    fb = store.allowed_first_bytes(union)
    mask = store.unpack(union)
    ref = np.zeros(256, bool)
    for tid in np.where(mask)[0]:
        tb = store.tokenizer.id_to_bytes[tid]
        if tb:
            ref[tb[0]] = True
    np.testing.assert_array_equal(fb, ref)


# ------------------------- jump-forward soundness -----------------------

@pytest.mark.parametrize("name", BUILTIN)
def test_jump_emits_only_oracle_valid_tokens(name, grammar_bundle, tokenizer):
    """Fuzz: from random valid-prefix texts, every token emitted by the
    jump analyzer (both modes) must pass a FRESH oracle's
    is_valid_extension at its emission point."""
    g, tab, store, gc = grammar_bundle(name)
    gs = GrammarSampler(g, seed=11)
    rng = np.random.default_rng(11)
    checked = 0
    for s in gs.sample_batch(8, budget=14, max_bytes=160):
        cut = int(rng.integers(0, len(s) + 1))
        prefix = s[:cut]
        try:
            gc.parser.partial_parse(prefix)
        except Exception:
            continue                      # cut landed outside L_p(G)
        for literal in (False, True):
            jr = jump_forward(gc, prefix, 12, literal=literal)
            oracle = GrammarConstraint(g, tab, store, tokenizer)
            cur = prefix
            for t in jr.tokens:
                assert oracle.is_valid_extension(cur, t), \
                    (name, literal, cur, t)
                cur += tokenizer.id_to_bytes[t]
                checked += 1
        # byte-level: every forced-literal prefix must stay in L_p(G)
        lit = forced_literal(gc, prefix, max_bytes=16)
        p2 = IncrementalParser(g, tab)
        for i in range(1, len(lit) + 1):
            p2.partial_parse(prefix + lit[:i])    # raises if outside L_p
            checked += 1
    if name == "jsonmsg":
        # whitespace-ignored grammars rarely force anything (a space is
        # always an alternative next byte) — the compact schema grammar
        # must actually exercise the property
        assert checked > 0


def test_forced_step_classifies_jsonmsg(grammar_bundle):
    g, tab, store, gc = grammar_bundle("jsonmsg")
    kind, tok, sm = gc.forced_step(b'[{"id":3,"op":"get","args":["x"')
    assert kind in ("free", "token")      # '"' may close or extend the arg
    # after a complete record list, ']' closes: popcount small but >1 is
    # fine; the interesting case is byte-forcing below


def test_forced_literal_jsonmsg_keys(grammar_bundle):
    """The compact schema grammar forces whole key literals at byte
    level even though several tokenizations survive in the mask."""
    g, tab, store, gc = grammar_bundle("jsonmsg")
    assert forced_literal(gc, b"[") == b'{"id":'
    assert forced_literal(gc, b'[{"id":3,') == b'"op":"'
    assert forced_literal(gc, b'[{"id":3,"op":"get",') == b'"args":['


def test_retokenize_aligned(tokenizer):
    # stable boundary: '=' cannot merge with '"'
    prefix = tokenizer.encode(b"x=")
    ids = retokenize_aligned(tokenizer, prefix, b'"name"')
    assert ids is not None
    assert b"".join(tokenizer.id_to_bytes[t] for t in ids) == b'"name"'
    # unstable boundary: the vocab holds a fused ' "' token, so canonical
    # encoding merges the prefix's trailing space with the literal's
    # opening quote -> the check must reject
    prefix2 = tokenizer.encode(b"x = ")
    assert retokenize_aligned(tokenizer, prefix2, b'"name"') is None


# ----------------------- engine-level equivalence -----------------------

@pytest.fixture(scope="module")
def spec_engine(tokenizer):
    from dataclasses import replace
    from repro.configs import get_config
    from repro.models.model import build_model
    bundles = {}
    for name in BUILTIN:
        g, tab = load_grammar(name)
        bundles[name] = (g, tab, build_mask_store(g, tokenizer))
    cfg = replace(get_config("syncode-demo"), vocab_size=tokenizer.vocab_size,
                  num_layers=2, d_model=128, d_ff=256, num_heads=4,
                  num_kv_heads=2, head_dim=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return Engine(model, params, tokenizer, bundles, max_len=200,
                  slots=4), bundles


def _reqs(gname, method="greedy", n=4, max_new=24, temp=1.0, seed0=0):
    return [Request(rid=i, prompt=b"say:", grammar=gname,
                    max_new_tokens=max_new,
                    decode=DecodeConfig(method=method, temperature=temp),
                    seed=seed0 + i) for i in range(n)]


@pytest.mark.parametrize("name", BUILTIN)
def test_greedy_spec_identical_to_plain_engine(name, spec_engine):
    """Acceptance criterion: greedy speculative decoding (default config)
    is token-for-token identical to the plain batched engine."""
    engine, bundles = spec_engine
    plain, _ = engine.generate(_reqs(name))
    spec, stats = engine.generate_speculative(_reqs(name))
    for a, b in zip(plain, spec):
        assert a.token_ids == b.token_ids, (name, a.generated, b.generated)
        assert a.finish_reason == b.finish_reason
        assert a.generated == b.generated
    assert stats.tokens == sum(s.steps for s in spec)


def test_greedy_spec_identical_with_more_requests_than_slots(spec_engine):
    engine, bundles = spec_engine
    n = 2 * engine.slots + 1
    plain, _ = engine.generate(_reqs("jsonmsg", n=n, max_new=16))
    spec, _ = engine.generate_speculative(_reqs("jsonmsg", n=n, max_new=16))
    for a, b in zip(plain, spec):
        assert a.token_ids == b.token_ids


def test_spec_sampling_outputs_stay_valid(spec_engine):
    """Sampling carries no token-equivalence claim, but the grammar
    guarantee must hold: completed outputs parse, partials stay in
    L_p(G)."""
    engine, bundles = spec_engine
    for name in ("json", "jsonmsg"):
        states, stats = engine.generate_speculative(
            _reqs(name, method="sample", temp=1.0, seed0=40))
        g, tab, _ = bundles[name]
        for st in states:
            assert st.finish_reason in ("eos", "length", "max_len")
            if st.finish_reason == "eos":
                assert IncrementalParser(g, tab).recognize(st.generated)
            else:
                IncrementalParser(g, tab).partial_parse(st.generated)


def test_literal_jump_outputs_valid_and_jump_heavy(spec_engine):
    """literal_jump=True trades exact token equivalence for longer jumps;
    byte-level grammar soundness must survive, and on the schema grammar
    a large fraction of tokens must come from jumps."""
    engine, bundles = spec_engine
    spec = SpecConfig(literal_jump=True)
    states, stats = engine.generate_speculative(
        _reqs("jsonmsg", n=4, max_new=40), spec=spec)
    g, tab, _ = bundles["jsonmsg"]
    for st in states:
        if st.finish_reason == "eos":
            assert IncrementalParser(g, tab).recognize(st.generated)
        else:
            IncrementalParser(g, tab).partial_parse(st.generated)
    assert stats.jump_tokens > 0
    assert stats.jump_fraction > 0.3
    # jumped tokens commit without a per-token decode: fewer device steps
    # than committed tokens
    assert stats.decode_steps < stats.tokens


def test_spec_mixed_pool_grammars_and_unconstrained(spec_engine):
    engine, bundles = spec_engine
    specs = [("json", "greedy"), ("calc", "sample"), (None, "greedy"),
             ("jsonmsg", "sample")]
    reqs = [Request(rid=i, prompt=b"say:", grammar=gname, max_new_tokens=14,
                    decode=DecodeConfig(method=m, temperature=1.0),
                    seed=70 + i)
            for i, (gname, m) in enumerate(specs)]
    states, _ = engine.generate_speculative(reqs)
    assert sorted(s.req.rid for s in states) == list(range(len(specs)))
    for st in states:
        if st.req.grammar is None:
            continue
        g, tab, _ = bundles[st.req.grammar]
        if st.finish_reason == "eos":
            assert IncrementalParser(g, tab).recognize(st.generated)
        else:
            IncrementalParser(g, tab).partial_parse(st.generated)


def test_spec_rejects_recurrent_arch(tokenizer):
    from dataclasses import replace
    from repro.configs import get_config
    from repro.models.model import build_model
    cfg = replace(get_config("syncode-demo"), arch_type="ssm",
                  vocab_size=tokenizer.vocab_size, num_layers=2,
                  d_model=64, d_ff=128)
    model = build_model(cfg)
    assert not model.supports_span_decode
    params = model.init(jax.random.PRNGKey(0))
    g, tab = load_grammar("calc")
    eng = Engine(model, params, tokenizer,
                 {"calc": (g, tab, build_mask_store(g, tokenizer))},
                 max_len=64, slots=2)
    with pytest.raises(ValueError, match="position-addressed"):
        eng.generate_speculative(_reqs("calc", n=1, max_new=4))


# ------------------------ span decode / kernel parity -------------------

def test_decode_span_matches_sequential_decode_steps(tokenizer):
    from dataclasses import replace
    from repro.configs import get_config
    from repro.models.model import build_model
    import jax.numpy as jnp
    cfg = replace(get_config("syncode-demo"), vocab_size=512, num_layers=2,
                  d_model=64, d_ff=128, num_heads=4, num_kv_heads=2,
                  head_dim=16)
    m = build_model(cfg)
    assert m.supports_span_decode
    params = m.init(jax.random.PRNGKey(1))
    B, L, S = 2, 32, 4
    rng = np.random.default_rng(2)
    prompt = jnp.asarray(rng.integers(3, 500, (B, 5)), jnp.int32)
    _, pc = m.prefill(params, {"tokens": prompt}, cache_len=L)
    toks = rng.integers(3, 500, (B, S)).astype(np.int32)
    c_seq = pc
    outs = []
    for i in range(S):
        o, c_seq = m.decode_step(params, c_seq, jnp.asarray(toks[:, i]),
                                 jnp.asarray(np.full(B, 5 + i, np.int32)))
        outs.append(np.asarray(o))
    o_span, c_span = m.decode_span(params, pc, jnp.asarray(toks),
                                   jnp.asarray(np.full(B, 5, np.int32)))
    np.testing.assert_array_equal(np.stack(outs, 1), np.asarray(o_span))
    for a, b in zip(jax.tree.leaves(c_seq), jax.tree.leaves(c_span)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_span_feed_mask_gates_cache_writes(tokenizer):
    from dataclasses import replace
    from repro.configs import get_config
    from repro.models.model import build_model
    import jax.numpy as jnp
    cfg = replace(get_config("syncode-demo"), vocab_size=512, num_layers=1,
                  d_model=64, d_ff=128, num_heads=4, num_kv_heads=2,
                  head_dim=16)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    B, L, S = 2, 16, 4
    rng = np.random.default_rng(3)
    prompt = jnp.asarray(rng.integers(3, 500, (B, 3)), jnp.int32)
    _, pc = m.prefill(params, {"tokens": prompt}, cache_len=L)
    toks = jnp.asarray(rng.integers(3, 500, (B, S)), jnp.int32)
    pos = jnp.asarray(np.full(B, 3, np.int32))
    fm = jnp.asarray(np.array([[True, True, False, False]] * B))
    _, c_masked = m.decode_span(params, pc, toks, pos, feed_mask=fm)
    c_two = pc
    for i in range(2):
        _, c_two = m.decode_step(params, c_two, toks[:, i],
                                 jnp.asarray(np.full(B, 3 + i, np.int32)))
    for a, b in zip(jax.tree.leaves(c_masked), jax.tree.leaves(c_two)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_masked_logits_span_kernel_matches_ref():
    import jax.numpy as jnp
    from repro.kernels.masked_logits.kernel import masked_logits_span
    from repro.kernels.masked_logits.ref import masked_logits_span_ref
    rng = np.random.default_rng(0)
    B, K, V, R, A = 3, 4, 256, 64, 6
    store = jnp.asarray(rng.integers(0, 2 ** 32, (R, V // 32),
                                     dtype=np.uint32))
    rows = jnp.asarray(rng.integers(-1, R, (B, K, A)).astype(np.int32))
    logits = jnp.asarray(rng.normal(size=(B, K, V)).astype(np.float32))
    eos = jnp.asarray(rng.integers(0, 2, (B, K)).astype(bool))
    cd = jnp.asarray(rng.integers(0, 2 ** 32, (B, K, V // 32),
                                  dtype=np.uint32))
    out = masked_logits_span(logits, store, rows, eos, cd, block_v=128,
                             interpret=True)
    ref = masked_logits_span_ref(logits, store, rows, eos, cd=cd)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_apply_grammar_mask_span_constrained_passthrough():
    import jax.numpy as jnp
    from repro.kernels.masked_logits.ops import apply_grammar_mask_span
    rng = np.random.default_rng(1)
    B, K, V, R, A = 2, 3, 128, 16, 4
    store = jnp.asarray(rng.integers(0, 2 ** 32, (R, V // 32),
                                     dtype=np.uint32))
    rows = jnp.asarray(rng.integers(0, R, (B, K, A)).astype(np.int32))
    logits = jnp.asarray(rng.normal(size=(B, K, V)).astype(np.float32))
    eos = jnp.asarray(np.zeros((B, K), bool))
    cons = jnp.asarray(np.array([[True, False, True],
                                 [False, False, True]]))
    out = apply_grammar_mask_span(logits, store, rows, eos, backend="jnp",
                                  constrained=cons)
    out = np.asarray(out)
    np.testing.assert_array_equal(out[0, 1], np.asarray(logits)[0, 1])
    np.testing.assert_array_equal(out[1, 0], np.asarray(logits)[1, 0])
    assert (out[0, 0] != np.asarray(logits)[0, 0]).any()
