"""Tensor-parallel (sharded) serving engine: token-for-token identity
with the single-device engine (docs/sharding.md).

The mesh tests need forced host devices and SKIP on a single-device
backend; CI runs them in the dedicated `host-mesh` job under

    XLA_FLAGS=--xla_force_host_platform_device_count=4

(locally: the same env var in front of pytest). The mesh-1 tests run
everywhere and keep the sharded code path covered by the default tier-1
suite.
"""
import jax
import pytest

from repro.core.decoding import DecodeConfig
from repro.core.grammars import BUILTIN
from repro.serving.engine import Engine, Request

MAX_LEN = 160

needs2 = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 devices (XLA_FLAGS="
           "--xla_force_host_platform_device_count=4; CI host-mesh job)")
needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >=4 devices (XLA_FLAGS="
           "--xla_force_host_platform_device_count=4; CI host-mesh job)")


@pytest.fixture(scope="module")
def harness(tokenizer, grammar_bundle):
    """One tiny model + every builtin grammar; a single-device baseline
    engine and a factory for mesh engines sharing the same params."""
    from dataclasses import replace

    from repro.configs import get_config
    from repro.models.model import build_model
    bundles = {}
    for name in BUILTIN:
        g, tab, store, _ = grammar_bundle(name)
        bundles[name] = (g, tab, store)
    cfg = get_config("syncode-demo")
    cfg = replace(cfg, vocab_size=tokenizer.vocab_size, num_layers=2,
                  d_model=128, d_ff=256, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    made = {}

    def make(mesh_size=None, **kw):
        key = (mesh_size, tuple(sorted(kw.items())))
        if key not in made:
            mesh = None
            if mesh_size is not None:
                from repro.launch.mesh import make_serving_mesh
                mesh = make_serving_mesh(mesh_size)
            kw.setdefault("slots", 4)
            made[key] = Engine(model, params, tokenizer, bundles,
                               max_len=MAX_LEN, mesh=mesh, **kw)
        return made[key]

    return make, bundles


def _reqs(grammar, n=4, max_new=12, method="greedy", temperature=0.9,
          top_k=None, top_p=None, prompt=b"Q: generate. A:", seed0=0):
    return [Request(rid=i, prompt=prompt, grammar=grammar,
                    max_new_tokens=max_new,
                    decode=DecodeConfig(method=method,
                                        temperature=temperature,
                                        top_k=top_k, top_p=top_p),
                    seed=seed0 + i) for i in range(n)]


def _assert_identical(base_states, mesh_states):
    assert len(base_states) == len(mesh_states)
    for a, b in zip(base_states, mesh_states):
        assert a.req.rid == b.req.rid
        assert a.token_ids == b.token_ids, (a.req.rid, a.generated,
                                            b.generated)
        assert a.finish_reason == b.finish_reason


# --------------------- mesh-1: always-on coverage --------------------------

def test_mesh1_generate_identical(harness):
    """A 1-device mesh exercises the whole sharded path (placements,
    use_sharding contexts, the selector gather) on any backend."""
    make, _ = harness
    base, m1 = make(), make(1)
    for gname in ("json", "calc"):
        bs, _ = base.generate(_reqs(gname, method="sample"))
        ms, stats = m1.generate(_reqs(gname, method="sample"))
        _assert_identical(bs, ms)
        assert stats.mesh_devices == 1


def test_mesh_requires_model_axis(harness):
    make, bundles = harness
    eng = make()
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="model"):
        Engine(eng.model, eng.params, eng.tok, bundles, mesh=mesh)


def test_serving_mesh_validates_device_count():
    from repro.launch.mesh import make_serving_mesh
    with pytest.raises(ValueError):
        make_serving_mesh(jax.device_count() + 1)
    with pytest.raises(ValueError):
        make_serving_mesh(0)


# ------------------ mesh 2 / 4: cross-device determinism -------------------

@needs2
@pytest.mark.parametrize("gname", sorted(BUILTIN))
def test_mesh2_generate_greedy_identical(harness, gname):
    make, _ = harness
    bs, _ = make().generate(_reqs(gname))
    ms, stats = make(2).generate(_reqs(gname))
    _assert_identical(bs, ms)
    assert stats.mesh_devices == 2


@needs4
@pytest.mark.parametrize("gname", sorted(BUILTIN))
def test_mesh4_generate_greedy_identical(harness, gname):
    make, _ = harness
    bs, _ = make().generate(_reqs(gname))
    ms, stats = make(4).generate(_reqs(gname))
    _assert_identical(bs, ms)
    assert stats.mesh_devices == 4


@needs2
@pytest.mark.parametrize("gname", sorted(BUILTIN))
def test_mesh2_generate_sampled_identical(harness, gname):
    """Sampled decoding: per-slot PRNG streams + the selector's single
    gather must reproduce the single-device draw exactly."""
    make, _ = harness
    reqs = lambda: _reqs(gname, method="sample", temperature=0.9,
                         top_k=40, top_p=0.95)
    bs, _ = make().generate(reqs())
    ms, _ = make(2).generate(reqs())
    _assert_identical(bs, ms)


@needs4
@pytest.mark.parametrize("gname", sorted(BUILTIN))
def test_mesh4_generate_sampled_identical(harness, gname):
    make, _ = harness
    reqs = lambda: _reqs(gname, method="sample", temperature=1.1)
    bs, _ = make().generate(reqs())
    ms, _ = make(4).generate(reqs())
    _assert_identical(bs, ms)


@needs2
@pytest.mark.parametrize("gname", sorted(BUILTIN))
def test_mesh2_speculative_greedy_identical(harness, gname):
    """Greedy speculative decoding (jump-forward + draft-verify spans)
    through the vocab-sharded mask/select path."""
    make, _ = harness
    bs, _ = make().generate_speculative(_reqs(gname))
    ms, _ = make(2).generate_speculative(_reqs(gname))
    _assert_identical(bs, ms)


@needs4
@pytest.mark.parametrize("gname", sorted(BUILTIN))
def test_mesh4_speculative_greedy_identical(harness, gname):
    make, _ = harness
    bs, _ = make().generate_speculative(_reqs(gname))
    ms, _ = make(4).generate_speculative(_reqs(gname))
    _assert_identical(bs, ms)


@needs2
def test_mesh2_paged_identical(harness):
    """Paged KV serving under the mesh: replicated page pools +
    vocab-sharded mask path, same tokens as the unsharded dense
    engine."""
    make, _ = harness
    bs, _ = make().generate(_reqs("json", method="sample"))
    ms, stats = make(2, paged=True, page_size=8).generate(
        _reqs("json", method="sample"))
    _assert_identical(bs, ms)
    assert stats.kv_peak_utilization > 0


@needs2
def test_mesh2_mixed_grammars_one_pool(harness):
    """Different grammars in one decode pool index one vocab-sharded
    concatenated store via per-slot row offsets."""
    make, _ = harness
    reqs = []
    for i, gname in enumerate(sorted(BUILTIN)):
        reqs.append(Request(rid=i, prompt=b"Q:", grammar=gname,
                            max_new_tokens=10,
                            decode=DecodeConfig(method="sample",
                                                temperature=0.9),
                            seed=i))
    bs, _ = make().generate(list(reqs))
    ms, _ = make(2).generate(list(reqs))
    _assert_identical(bs, ms)


@needs2
def test_mesh2_store_is_sharded(harness):
    """The packed store actually lives vocab-sharded on the mesh (not
    silently replicated): its sharding splits the word axis."""
    make, _ = harness
    eng = make(2)
    sh = eng._store_cat.sharding
    spec = sh.spec
    assert spec[1] == "model", spec
    assert eng.params["embed_block"]["embed"].sharding.spec[0] == "model"


# --------------------- async engine on the mesh ----------------------------
# The AsyncEngine drives the same StepLoop as the sync entry points, so
# mesh identity must survive the async front-end too (the CI host-mesh
# job runs these alongside the sync determinism tests above).

def _async_generate(engine, reqs):
    import asyncio

    from repro.serving.async_engine import AsyncEngine

    async def go():
        aeng = AsyncEngine(engine)
        try:
            return await aeng.generate(reqs)
        finally:
            await aeng.drain()
    return asyncio.run(go())


def test_mesh1_async_generate_identical(harness):
    """Always-on: async + overlap on a 1-device mesh matches the
    unsharded sync baseline token-for-token."""
    make, _ = harness
    bs, _ = make().generate(_reqs("json", method="sample"))
    ms, stats = _async_generate(make(1), _reqs("json", method="sample"))
    _assert_identical(bs, ms)
    assert stats.mesh_devices == 1


@needs2
@pytest.mark.parametrize("gname", sorted(BUILTIN))
def test_mesh2_async_generate_identical(harness, gname):
    make, _ = harness
    bs, _ = make().generate(_reqs(gname, method="sample",
                                  temperature=1.0))
    ms, stats = _async_generate(make(2), _reqs(gname, method="sample",
                                               temperature=1.0))
    _assert_identical(bs, ms)
    assert stats.mesh_devices == 2


@needs2
def test_mesh2_async_paged_identical(harness):
    make, _ = harness
    bs, _ = make().generate(_reqs("json", n=5, max_new=10))
    ms, _ = _async_generate(make(2, paged=True, page_size=8),
                            _reqs("json", n=5, max_new=10))
    _assert_identical(bs, ms)


@needs2
def test_mesh2_async_speculative_identical(harness):
    import asyncio

    from repro.serving.async_engine import AsyncEngine
    from repro.spec import SpecConfig
    make, _ = harness
    bs, _ = make().generate_speculative(_reqs("jsonmsg"),
                                        spec=SpecConfig())

    async def go():
        aeng = AsyncEngine(make(2), spec=SpecConfig())
        try:
            return await aeng.generate(_reqs("jsonmsg"))
        finally:
            await aeng.drain()
    ms, _ = asyncio.run(go())
    _assert_identical(bs, ms)
