"""Paged attention: the Pallas kernel, the jnp gather reference and the
dense decode path must agree BIT-EXACTLY (the engine's token-for-token
equivalence claim rests on it)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade gracefully: only @given tests skip
    from tests._hypothesis_stub import given, settings, st

from repro.kernels.paged_attention.kernel import (paged_attention_decode,
                                                  paged_attention_span)
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref

NEG_INF = -1e30


def _setup(rng, B, S, H, K, Dh, ps, nP, P, dtype=np.float32, min_pos=None):
    """Random pools + disjoint per-slot page tables + start positions
    with pos + S - 1 inside the mapped region."""
    q = jnp.asarray(rng.normal(size=(B, S, H, Dh)).astype(dtype))
    kp = jnp.asarray(rng.normal(size=(P, ps, K, Dh)).astype(dtype))
    vp = jnp.asarray(rng.normal(size=(P, ps, K, Dh)).astype(dtype))
    perm = rng.permutation(P)
    pt = np.full((B, nP), -1, np.int32)
    pos = np.zeros(B, np.int32)
    off = 0
    for b in range(B):
        n = int(rng.integers(1, nP + 1))
        n = max(n, -(-S // ps))          # mapped region must cover the span
        pt[b, :n] = perm[off:off + n]
        off += n
        hi = n * ps - S
        lo = 0 if min_pos is None else min(min_pos, hi)
        pos[b] = int(rng.integers(lo, hi + 1))
    return q, kp, vp, jnp.asarray(pt), jnp.asarray(pos)


def _dense_twin(q, kp, vp, pt, pos):
    """The dense decode path's exact math (layers._self_attention_decode)
    on a densely materialized copy of the paged cache."""
    P, ps, K, Dh = kp.shape
    B, S, H, _ = q.shape
    nP = pt.shape[1]
    L = nP * ps
    ptn = np.asarray(pt)
    kc = np.zeros((B, L, K, Dh), np.asarray(kp).dtype)
    vc = np.zeros((B, L, K, Dh), np.asarray(vp).dtype)
    kv_pos = np.full((B, L), -1, np.int32)
    for b in range(B):
        for j in range(nP):
            if ptn[b, j] >= 0:
                kc[b, j * ps:(j + 1) * ps] = np.asarray(kp)[ptn[b, j]]
                vc[b, j * ps:(j + 1) * ps] = np.asarray(vp)[ptn[b, j]]
                kv_pos[b, j * ps:(j + 1) * ps] = np.arange(j * ps,
                                                           (j + 1) * ps)
    qpos = np.asarray(pos)[:, None] + np.arange(S)[None, :]
    valid = (kv_pos[:, None, :] >= 0) & \
        (kv_pos[:, None, :] <= qpos[:, :, None])
    scale = 1.0 / (Dh ** 0.5)
    G = H // K
    qg = (q * scale).reshape(B, S, K, G, Dh)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, jnp.asarray(kc),
                   preferred_element_type=jnp.float32)
    s = jnp.where(jnp.asarray(valid)[:, None, None, :, :], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", pr.astype(vc.dtype),
                   jnp.asarray(vc), preferred_element_type=jnp.float32)
    return o.reshape(B, S, H, Dh).astype(q.dtype)


def _bits(x):
    return np.asarray(jnp.asarray(x).astype(jnp.float32))


def test_span_kernel_bit_exact_vs_ref_and_dense():
    rng = np.random.default_rng(0)
    args = _setup(rng, B=3, S=4, H=8, K=4, Dh=32, ps=8, nP=5, P=16)
    ref = paged_attention_ref(*args)
    ker = paged_attention_span(*args, interpret=True)
    dense = _dense_twin(*args)
    np.testing.assert_array_equal(_bits(ref), _bits(ker))
    np.testing.assert_array_equal(_bits(ref), _bits(dense))


def test_decode_variant_bit_exact():
    rng = np.random.default_rng(1)
    q, kp, vp, pt, pos = _setup(rng, B=4, S=1, H=4, K=2, Dh=16,
                                ps=4, nP=6, P=32)
    ref = paged_attention_ref(q, kp, vp, pt, pos)
    ker = paged_attention_decode(q[:, 0], kp, vp, pt, pos, interpret=True)
    np.testing.assert_array_equal(_bits(ref[:, 0]), _bits(ker))


def test_bfloat16_bit_exact():
    rng = np.random.default_rng(2)
    q, kp, vp, pt, pos = _setup(rng, B=2, S=2, H=4, K=2, Dh=16,
                                ps=4, nP=4, P=12)
    q, kp, vp = (x.astype(jnp.bfloat16) for x in (q, kp, vp))
    ref = paged_attention_ref(q, kp, vp, pt, pos)
    ker = paged_attention_span(q, kp, vp, pt, pos, interpret=True)
    dense = _dense_twin(q, kp, vp, pt, pos)
    np.testing.assert_array_equal(_bits(ref), _bits(ker))
    np.testing.assert_array_equal(_bits(ref), _bits(dense))


def test_unmapped_pages_never_contribute():
    """Entries behind -1 page-table slots must be invisible even when
    the pool rows they'd alias hold huge values."""
    rng = np.random.default_rng(3)
    q, kp, vp, pt, pos = _setup(rng, B=2, S=2, H=4, K=2, Dh=16,
                                ps=4, nP=4, P=12)
    ref = paged_attention_ref(q, kp, vp, pt, pos)
    poisoned = kp.at[0].set(1e4)   # page 0 = the clamp target of -1 slots
    pt2 = np.asarray(pt).copy()
    assert (pt2 == 0).sum() <= 1   # page 0 mapped at most once
    mask0 = ~(pt2 == 0).any(axis=1)
    ref2 = paged_attention_ref(q, poisoned, vp, jnp.asarray(pt2), pos)
    # slots that never map page 0 are unchanged by the poison
    np.testing.assert_array_equal(_bits(ref)[mask0], _bits(ref2)[mask0])


def test_ops_dispatcher_backends_agree():
    rng = np.random.default_rng(4)
    args = _setup(rng, B=2, S=3, H=4, K=4, Dh=16, ps=4, nP=4, P=12)
    a = paged_attention(*args, backend="jnp")
    b = paged_attention(*args, backend="pallas")
    c = paged_attention(*args, backend="auto")
    np.testing.assert_array_equal(_bits(a), _bits(b))
    np.testing.assert_array_equal(_bits(a), _bits(c))


@settings(max_examples=10, deadline=None)
@given(
    B=st.sampled_from([1, 2, 3]),
    S=st.sampled_from([1, 2, 4]),
    HK=st.sampled_from([(4, 2), (4, 4), (8, 2)]),
    ps=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 2 ** 16),
)
def test_fuzz_kernel_parity(B, S, HK, ps, seed):
    H, K = HK
    rng = np.random.default_rng(seed)
    nP = int(rng.integers(max(1, -(-(S + 1) // ps)), 6))
    P = B * nP + 2
    args = _setup(rng, B=B, S=S, H=H, K=K, Dh=8, ps=ps, nP=nP, P=P)
    ref = paged_attention_ref(*args)
    ker = paged_attention_span(*args, interpret=True)
    dense = _dense_twin(*args)
    np.testing.assert_array_equal(_bits(ref), _bits(ker))
    np.testing.assert_array_equal(_bits(ref), _bits(dense))
