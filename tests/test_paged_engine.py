"""Paged KV engine end-to-end: token-for-token identity with the dense
engine (the ISSUE 3 acceptance bar), prefix sharing, chunked prefill,
eviction and graceful pool exhaustion."""
import jax
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # degrade gracefully: only @given tests skip
    from tests._hypothesis_stub import given, settings, st

from repro.core.decoding import DecodeConfig
from repro.core.grammars import BUILTIN
from repro.core.parser import IncrementalParser
from repro.serving.engine import Engine, Request
from repro.spec import SpecConfig

MAX_LEN = 160


@pytest.fixture(scope="module")
def engines(tokenizer, grammar_bundle):
    """One tiny model, every builtin grammar, a dense engine and a paged
    twin sharing the same params."""
    from dataclasses import replace

    from repro.configs import get_config
    from repro.models.model import build_model
    bundles = {}
    for name in BUILTIN:
        g, tab, store, _ = grammar_bundle(name)
        bundles[name] = (g, tab, store)
    cfg = get_config("syncode-demo")
    cfg = replace(cfg, vocab_size=tokenizer.vocab_size, num_layers=2,
                  d_model=128, d_ff=256, num_heads=4, num_kv_heads=2,
                  head_dim=32)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def make(**kw):
        kw.setdefault("slots", 4)
        return Engine(model, params, tokenizer, bundles, max_len=MAX_LEN,
                      **kw)

    return make(), make(paged=True, page_size=8), bundles, make


def _reqs(grammar, n=3, max_new=16, method="greedy", temperature=1.0,
          top_k=None, top_p=None, prompt=b"Q: generate. A:", seed0=0):
    return [Request(rid=i, prompt=prompt, grammar=grammar,
                    max_new_tokens=max_new,
                    decode=DecodeConfig(method=method,
                                        temperature=temperature,
                                        top_k=top_k, top_p=top_p),
                    seed=seed0 + i) for i in range(n)]


def _assert_identical(dense_states, paged_states):
    assert len(dense_states) == len(paged_states)
    for a, b in zip(dense_states, paged_states):
        assert a.req.rid == b.req.rid
        assert a.token_ids == b.token_ids, (a.req.rid, a.generated,
                                            b.generated)
        assert a.finish_reason == b.finish_reason


def test_generate_identical_all_builtin_grammars(engines):
    dense, paged, bundles, _ = engines
    for gname in BUILTIN:
        ds, _ = dense.generate(_reqs(gname))
        ps, stats = paged.generate(_reqs(gname))
        _assert_identical(ds, ps)
        assert stats.kv_peak_utilization > 0


def test_generate_identical_sampled(engines):
    dense, paged, _, _ = engines
    for kw in ({"temperature": 0.9}, {"temperature": 1.2, "top_k": 8},
               {"temperature": 0.8, "top_p": 0.9}):
        ds, _ = dense.generate(_reqs("json", method="sample", **kw))
        ps, _ = paged.generate(_reqs("json", method="sample", **kw))
        _assert_identical(ds, ps)


def test_speculative_greedy_identical(engines):
    """Greedy speculative + paging == dense plain engine, token for
    token (jump-forward and draft-verify on top of page tables)."""
    dense, paged, _, _ = engines
    for gname, spec in (("json", SpecConfig()),
                        ("jsonmsg", SpecConfig())):
        ds, _ = dense.generate(_reqs(gname, max_new=20))
        ps, stats = paged.generate_speculative(_reqs(gname, max_new=20),
                                               spec=spec)
        _assert_identical(ds, ps)


def test_prefix_sharing_and_chunked_prefill(engines):
    """Slots admitted with one shared long prompt attach its pages
    instead of re-prefilling: prefix_hit_rate > 0, far fewer page
    allocations than unshared admission would need, and output still
    identical to the dense engine."""
    dense, paged, _, _ = engines
    prompt = (b'{"type": "msg", "seq": 1, "body": "hello"} ' * 3)[:100]
    n = 4
    ds, _ = dense.generate(_reqs("json", n=n, max_new=10, prompt=prompt))
    ps, stats = paged.generate(_reqs("json", n=n, max_new=10,
                                     prompt=prompt))
    _assert_identical(ds, ps)
    assert stats.prefix_hit_rate > 0.5
    # the shared prefix is stored once: allocations stay well below
    # n * pages(prompt)
    pages_per_prompt = (len(prompt) + 1) // paged.page_size
    assert stats.kv_page_allocs < n * pages_per_prompt
    assert 0 < stats.kv_peak_utilization <= 1.0
    assert stats.kv_pages_in_use > 0          # cold cache retained


def test_more_requests_than_slots_identical(engines):
    dense, paged, bundles, _ = engines
    n = 2 * dense.slots + 1
    ds, _ = dense.generate(_reqs("json", n=n, max_new=10,
                                 method="sample", temperature=1.0,
                                 seed0=10))
    ps, _ = paged.generate(_reqs("json", n=n, max_new=10,
                                 method="sample", temperature=1.0,
                                 seed0=10))
    _assert_identical(ds, ps)
    g, tab, _ = bundles["json"]
    for st in ps:
        if st.finish_reason == "eos":
            assert IncrementalParser(g, tab).recognize(st.generated)
        else:
            IncrementalParser(g, tab).partial_parse(st.generated)


def test_mixed_grammars_one_pool_identical(engines):
    dense, paged, _, _ = engines
    specs = [("json", 0), ("calc", 1), (None, 2), ("jsonmsg", 3)]
    reqs = lambda: [Request(rid=i, prompt=b"say:", grammar=gname,
                            max_new_tokens=12,
                            decode=DecodeConfig(method="sample",
                                                temperature=1.0),
                            seed=40 + i) for gname, i in specs]
    ds, _ = dense.generate(reqs())
    ps, _ = paged.generate(reqs())
    _assert_identical(ds, ps)


def test_kv_oom_finishes_gracefully(engines):
    """A pool too small for every slot's full generation finishes the
    overflowing requests with 'kv_oom' instead of crashing, and the
    others still complete with the grammar guarantee intact."""
    _, _, bundles, make = engines
    eng = make(paged=True, page_size=4, num_pages=14, slots=2)
    states, stats = eng.generate(_reqs("json", n=2, max_new=120,
                                       prompt=b"x" * 20))
    assert len(states) == 2
    for st in states:
        assert st.finish_reason in ("eos", "length", "max_len", "kv_oom")
    assert any(st.finish_reason == "kv_oom" for st in states)
    g, tab, _ = bundles["json"]
    for st in states:
        IncrementalParser(g, tab).partial_parse(st.generated)


def test_eviction_recycles_cold_cache(engines):
    """Distinct prompts under a small pool evict LRU cold pages instead
    of dying; every request still completes."""
    _, _, _, make = engines
    eng = make(paged=True, page_size=4, num_pages=24, slots=2)
    reqs = [Request(rid=i, prompt=bytes([65 + i]) * 30, grammar="calc",
                    max_new_tokens=8, decode=DecodeConfig(method="greedy"),
                    seed=i) for i in range(6)]
    states, stats = eng.generate(reqs)
    assert len(states) == 6
    assert all(s.finish_reason in ("eos", "length", "max_len")
               for s in states)
    assert stats.kv_evictions > 0


def test_paged_rejects_recurrent_arch(tokenizer):
    from dataclasses import replace

    from repro.configs import get_config
    from repro.models.model import build_model
    cfg = get_config("mamba2-370m")
    cfg = replace(cfg, vocab_size=tokenizer.vocab_size, num_layers=1,
                  d_model=64)
    model = build_model(cfg)
    with pytest.raises(ValueError, match="position-addressed"):
        Engine(model, {}, tokenizer, {}, paged=True)


def test_recurrent_archs_keep_exact_length_prefill(tokenizer):
    """Bucket padding is gated OFF for rec/ssm layer kinds: their
    carried state would fold the zero-pad tail in (true_len can only
    mask attention caches)."""
    from dataclasses import replace

    from repro.configs import get_config
    from repro.models.model import build_model
    cfg = get_config("recurrentgemma-9b")
    cfg = replace(cfg, vocab_size=tokenizer.vocab_size, num_layers=3,
                  d_model=64, d_ff=128, num_heads=4, num_kv_heads=2,
                  head_dim=16, lru_width=64)
    model = build_model(cfg)
    assert not model.prefill_padding_safe
    eng = Engine(model, {}, tokenizer, {}, max_len=MAX_LEN)
    prompt, n = eng._bucketed_prompt(list(range(10)))
    assert prompt.shape == (1, 10) and n == 10      # no padding
    demo = get_config("syncode-demo")
    assert build_model(demo).prefill_padding_safe   # attn-only: padded
    prompt, n = Engine(build_model(demo), {}, tokenizer, {},
                       max_len=MAX_LEN)._bucketed_prompt(list(range(10)))
    assert prompt.shape == (1, 16) and n == 10


def test_request_state_reports_pages(engines):
    _, paged, _, _ = engines
    states, _ = paged.generate(_reqs("calc", n=2, max_new=8))
    for st in states:
        assert st.kv_pages > 0
        assert st.prompt_len > 0


def _capture_alloc(eng):
    """Wrap the engine's _paged_setup so the run's allocator is
    observable after generate() returns."""
    orig = eng._paged_setup
    box = {}

    def patched(B):
        alloc, caches = orig(B)
        box["alloc"] = alloc
        return alloc, caches
    eng._paged_setup = patched
    return box


def _assert_pool_at_baseline(alloc):
    """After every request finished, the only pages still referenced
    must be cache-cold full prompt pages (evictable); anything else is
    a refcount leak from a dead slot."""
    alloc.check_invariants()
    assert all(not t for t in alloc.tables)
    assert alloc.pages_in_use == alloc.cold_pages
    leaked = [p for p in range(alloc.P)
              if alloc.refcount[p] > 0 and not
              (alloc.refcount[p] == 1 and p in alloc._rev and alloc.full[p])]
    assert not leaked, leaked


def test_kv_oom_releases_pages_to_baseline(engines):
    """Regression (kv_oom audit): requests finished with 'kv_oom' must
    return every page they held — including pages acquired earlier in
    the failed multi-page feed — so the pool drains back to baseline."""
    _, _, _, make = engines
    eng = make(paged=True, page_size=4, num_pages=14, slots=4)
    box = _capture_alloc(eng)
    states, stats = eng.generate(_reqs("json", n=6, max_new=60,
                                       method="sample", temperature=0.9,
                                       prompt=b"Q: generate stuff. A:"))
    assert any(s.finish_reason == "kv_oom" for s in states)
    _assert_pool_at_baseline(box["alloc"])


def test_kv_oom_speculative_releases_pages_to_baseline(engines):
    """Same through generate_speculative's feed path (span feeds cross
    several page boundaries at once)."""
    _, _, _, make = engines
    eng = make(paged=True, page_size=2, num_pages=30, slots=4)
    box = _capture_alloc(eng)
    states, _ = eng.generate_speculative(
        _reqs("json", n=6, max_new=48, prompt=b"Q: gen. A:"),
        spec=SpecConfig())
    assert len(states) == 6
    _assert_pool_at_baseline(box["alloc"])


@settings(max_examples=8, deadline=None)
@given(num_pages=st.integers(10, 22), seed0=st.integers(0, 1000))
def test_kv_oom_baseline_fuzz(engines, num_pages, seed0):
    """Hypothesis regression: across pool sizes and seeds, a run that
    hits PoolExhausted (or not) always drains the pool to baseline."""
    _, _, _, make = engines
    eng = make(paged=True, page_size=4, num_pages=num_pages, slots=3)
    box = _capture_alloc(eng)
    try:
        states, _ = eng.generate(_reqs("json", n=5, max_new=40,
                                       method="sample", temperature=0.9,
                                       seed0=seed0,
                                       prompt=b"Q: generate stuff. A:"))
    except Exception as e:
        # a pool too small for even one prompt raises before admitting
        from repro.serving.kvpool import PoolExhausted
        assert isinstance(e, PoolExhausted)
    _assert_pool_at_baseline(box["alloc"])


def test_paged_repeated_runs_identical(engines):
    """Regression for the feed_pos zero-copy aliasing race (PR 5
    addendum in CHANGES.md): chunked-prefill steps used to mutate the
    live feed_pos array while the async span feed could still alias it,
    corrupting the prefill region's logits on some executions. Repeated
    runs of the same shared-prefix workload must be token-identical."""
    _, paged, _, _ = engines
    prompt = (b'{"type": "msg", "seq": 1, "body": "hello"} ' * 3)[:100]
    ref = None
    for _ in range(3):
        states, _ = paged.generate(_reqs("json", n=4, max_new=8,
                                         prompt=prompt))
        sig = [s.token_ids for s in states]
        if ref is None:
            ref = sig
        assert sig == ref, "paged engine nondeterministic across runs"
