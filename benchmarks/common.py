"""Shared benchmark substrate: demo engine construction, measurement,
and the schema-versioned JSON artifact layer behind the perf-regression
observatory.

Every `emit()` call still prints the historical ``name,us,derived`` CSV
row, but now also collects the row in-process; `write_artifact()`
persists the run as ``BENCH_<git-sha>.json``:

    {"schema_version": 2,
     "run_meta": {git_sha, git_dirty, jax_version, device_kind, ...},
     "rows": [{"name", "us_per_call",
               "derived": {k: v, ...},          # parsed k=v columns
               "attribution": {host_grammar_s, host_grammar_ci_s,
                               host_grammar_cd_s, mask_sample_kernel_s,
                               forward_kernel_s, overlap_hidden_s,
                               device_forward_s, device_mask_sample_s}},
              ...]}

`scripts/bench_diff.py` compares two such artifacts with median + MAD
tolerance bands (`make bench-regress` in CI); committed baselines live
in benchmarks/baselines/ (artifacts/ is gitignored — runtime outputs
land there by default). Rows printed by subprocess benches (the sharded
table re-executes under XLA_FLAGS) are re-absorbed via `collect_line()`
so the artifact covers every row the console shows.
"""
from __future__ import annotations

import json
import os
import re
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

SCHEMA_VERSION = 2

# canonical attribution columns every artifact row carries (zero when a
# bench has no engine stats — micro-benches of pure host code). v2 adds
# the context-split host sub-components host_grammar_ci_s /
# host_grammar_cd_s (subsets of host_grammar_s, not additive with it);
# scripts/bench_diff.py still reads v1 artifacts by zero-filling them.
ATTRIBUTION_COLS = ("host_grammar_s", "host_grammar_ci_s",
                    "host_grammar_cd_s", "mask_sample_kernel_s",
                    "forward_kernel_s", "overlap_hidden_s",
                    "device_forward_s", "device_mask_sample_s")


def build_demo(grammars=("json",), vocab=2048, opportunistic=False,
               seed=0, max_len=400, slots=4, **engine_kw):
    from repro.launch.serve import build_engine
    return build_engine("syncode-demo", grammars=grammars, vocab=vocab,
                        opportunistic=opportunistic, seed=seed,
                        max_len=max_len, slots=slots, **engine_kw)


def timeit(fn, n=5, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n


_RUN_META = None


def run_meta_dict() -> dict:
    """Build identity as a dict — the same probe /healthz serves
    (obs/buildinfo), so bench artifacts and scraped metrics correlate
    on identical fields."""
    from repro.obs import build_info
    return build_info()


def run_meta() -> str:
    """Provenance stamp appended to every CSV row: git SHA, jax version
    and device kind — so bench trajectories stay attributable when
    compared across commits and machines. Computed once per process;
    ';'-joined key=value pairs matching the derived-column idiom."""
    global _RUN_META
    if _RUN_META is None:
        info = run_meta_dict()
        dev = str(info["device_kind"]).replace(",", " ") \
            .replace(";", " ").replace("=", " ").strip() or "unknown"
        _RUN_META = (f"git={info['git_sha']};jax={info['jax_version']};"
                     f"device={dev}")
    return _RUN_META


def attribution_cols(stats) -> dict:
    """Standard attribution columns from an EngineStats (serving/engine):
    the host/kernel/overlap split every artifact row carries."""
    a = getattr(stats, "attribution", None) or {}
    sec = a.get("seconds", {})
    return {
        "host_grammar_s": sec.get("host_grammar", 0.0),
        "host_grammar_ci_s": sec.get("host_grammar_ci", 0.0),
        "host_grammar_cd_s": sec.get("host_grammar_cd", 0.0),
        "mask_sample_kernel_s": sec.get("mask_sample_kernel", 0.0),
        "forward_kernel_s": sec.get("forward_kernel", 0.0),
        "overlap_hidden_s": getattr(stats, "overlap_hidden_s", 0.0),
        "device_forward_s": getattr(stats, "device_forward_s", 0.0),
        "device_mask_sample_s": getattr(stats, "device_mask_sample_s",
                                        0.0),
    }


def _parse_derived(derived: str) -> dict:
    out = {}
    for part in derived.split(";"):
        k, sep, v = part.partition("=")
        if not sep or not k:
            continue
        try:
            out[k] = int(v) if re.fullmatch(r"[+-]?\d+", v) \
                else float(v)
        except ValueError:
            out[k] = v
    return out


_ROWS: list[dict] = []


def emit(name: str, us_per_call: float, derived: str = "",
         stats=None) -> None:
    """Print the CSV row AND collect it for the JSON artifact. `stats`
    (an EngineStats) populates the attribution columns; benches without
    engine involvement leave them zero."""
    attr = attribution_cols(stats) if stats is not None \
        else {k: 0.0 for k in ATTRIBUTION_COLS}
    _ROWS.append({"name": name, "us_per_call": float(us_per_call),
                  "derived": _parse_derived(derived),
                  "attribution": attr})
    if stats is not None:
        # print the attribution split too, so rows emitted by subprocess
        # benches round-trip through collect_line() with attribution
        cols = ";".join(f"{k}={v:.6f}" for k, v in attr.items())
        derived = f"{derived};{cols}" if derived else cols
    derived = f"{derived};{run_meta()}" if derived else run_meta()
    print(f"{name},{us_per_call:.1f},{derived}")


_ROW_RE = re.compile(r"^([\w.\-]+),([0-9.eE+-]+),(.*)$")


def collect_line(line: str) -> bool:
    """Absorb a ``name,us,derived`` row printed by a subprocess bench
    into this process's artifact rows. Returns True iff parsed."""
    m = _ROW_RE.match(line.strip())
    if not m or m.group(1) == "name":        # skip the CSV header
        return False
    try:
        us = float(m.group(2))
    except ValueError:
        return False
    derived = _parse_derived(m.group(3))
    attr = {k: float(derived.pop(k)) if k in derived else 0.0
            for k in ATTRIBUTION_COLS}
    _ROWS.append({"name": m.group(1), "us_per_call": us,
                  "derived": derived, "attribution": attr})
    return True


def rows() -> list[dict]:
    return list(_ROWS)


def clear_rows() -> None:
    _ROWS.clear()


def default_artifact_path() -> str:
    info = run_meta_dict()
    d = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                     "bench")
    return os.path.join(d, f"BENCH_{info['git_sha']}.json")


def write_artifact(path: str | None = None,
                   extra_meta: dict | None = None) -> str:
    """Persist every collected row as the schema-versioned regression
    artifact (benchmarks/README.md documents the schema)."""
    path = path or default_artifact_path()
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    meta = run_meta_dict()
    meta["unix_time"] = time.time()
    if extra_meta:
        meta.update(extra_meta)
    doc = {"schema_version": SCHEMA_VERSION, "run_meta": meta,
           "rows": rows()}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return path
