"""Shared benchmark substrate: demo engine construction + measurement."""
from __future__ import annotations

import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np


def build_demo(grammars=("json",), vocab=2048, opportunistic=False,
               seed=0, max_len=400, slots=4, **engine_kw):
    from repro.launch.serve import build_engine
    return build_engine("syncode-demo", grammars=grammars, vocab=vocab,
                        opportunistic=opportunistic, seed=seed,
                        max_len=max_len, slots=slots, **engine_kw)


def timeit(fn, n=5, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n


_RUN_META = None


def run_meta() -> str:
    """Provenance stamp appended to every CSV row: git SHA, jax version
    and device kind — so bench trajectories stay attributable when
    compared across commits and machines. Computed once per process;
    ';'-joined key=value pairs matching the derived-column idiom."""
    global _RUN_META
    if _RUN_META is None:
        try:
            sha = subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                cwd=os.path.dirname(os.path.abspath(__file__)),
                capture_output=True, text=True, timeout=10,
            ).stdout.strip() or "unknown"
        except Exception:
            sha = "unknown"
        dev = jax.devices()[0].device_kind.replace(",", " ") \
            .replace(";", " ").replace("=", " ").strip() or "unknown"
        _RUN_META = (f"git={sha};jax={jax.__version__};"
                     f"device={dev}")
    return _RUN_META


def emit(name: str, us_per_call: float, derived: str = ""):
    derived = f"{derived};{run_meta()}" if derived else run_meta()
    print(f"{name},{us_per_call:.1f},{derived}")
