"""Shared benchmark substrate: demo engine construction + measurement."""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np


def build_demo(grammars=("json",), vocab=2048, opportunistic=False,
               seed=0, max_len=400, slots=4, **engine_kw):
    from repro.launch.serve import build_engine
    return build_engine("syncode-demo", grammars=grammars, vocab=vocab,
                        opportunistic=opportunistic, seed=seed,
                        max_len=max_len, slots=slots, **engine_kw)


def timeit(fn, n=5, warmup=1):
    for _ in range(warmup):
        fn()
    t0 = time.time()
    for _ in range(n):
        fn()
    return (time.time() - t0) / n


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")
