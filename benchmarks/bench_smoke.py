"""CI smoke benchmark (`make bench-smoke`): a few steps of the PAGED
engine on a tiny model — proves the paged serving stack (page-table
attention, prefix sharing, chunked prefill, stats plumbing) end-to-end
in seconds, without the full `make bench` matrix.

Exits non-zero if the run produces no tokens, violates the grammar
guarantee, or reports no prefix sharing on a shared-prompt batch.
"""
from __future__ import annotations

import sys
import time

from .common import build_demo, emit


def main(slots=4, n=6, max_new=8) -> int:
    from repro.core.decoding import DecodeConfig
    from repro.core.parser import IncrementalParser
    from repro.serving.engine import Request

    engine, bundles, tok = build_demo(("json",), vocab=512, max_len=96,
                                      slots=slots, paged=True,
                                      page_size=8, devtime=True)
    prompt = b'{"k": [1, 2]} smoke prompt shared by every request'
    reqs = [Request(rid=i, prompt=prompt, grammar="json",
                    max_new_tokens=max_new,
                    decode=DecodeConfig(method="sample", temperature=0.9),
                    seed=i) for i in range(n)]
    t0 = time.time()
    states, stats = engine.generate(reqs)
    wall = time.time() - t0

    g, tab, _ = bundles["json"]
    ok = True
    for st in states:
        if st.finish_reason == "eos" and \
                not IncrementalParser(g, tab).recognize(st.generated):
            print(f"bench-smoke: INVALID eos output {st.generated!r}")
            ok = False
        elif st.finish_reason not in ("eos", "length", "max_len"):
            print(f"bench-smoke: bad finish_reason {st.finish_reason}")
            ok = False
    if stats.tokens <= 0:
        print("bench-smoke: no tokens generated")
        ok = False
    if stats.prefix_hit_rate <= 0:
        print("bench-smoke: shared prompts produced no prefix hits")
        ok = False
    emit("bench_smoke_paged", wall / max(stats.tokens, 1) * 1e6,
         f"tok_s={stats.tokens_per_sec:.1f};tokens={stats.tokens};"
         f"requests={stats.requests};"
         f"prefix_hit_rate={stats.prefix_hit_rate:.2f};"
         f"kv_pages_in_use={stats.kv_pages_in_use};"
         f"kv_peak_utilization={stats.kv_peak_utilization:.3f}",
         stats=stats)
    print(f"bench-smoke: {'OK' if ok else 'FAILED'} "
          f"({stats.tokens} tokens, {wall:.1f}s)")
    return 0 if ok else 1


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="also write the bench artifact (bench_diff "
                         "input) to PATH")
    args = ap.parse_args()
    rc = main()
    if args.json_out:
        from .common import write_artifact
        print(f"wrote {write_artifact(args.json_out)}", file=sys.stderr)
    sys.exit(rc)
