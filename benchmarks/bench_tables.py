"""Benchmarks mirroring the paper's tables (scaled to the CPU demo
substrate; trends and invariants, not absolute numbers — DESIGN.md §5).

Table 1 (JSON): syntax errors + generation time, SynCode vs standard.
Table 2 (SQL): validity/"executability" proxy + tokens + time.
Table 3 (GPL): syntax-error reduction on the GPL stand-in (minilang).
Table 5: mask-store creation time/memory vs vocabulary size.
Fig. 10: per-step overhead, incremental parsing vs from scratch.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from .common import build_demo, collect_line, emit, timeit


def _run_requests(engine, grammar, n, max_new, constrained=True, seed0=0,
                  temperature=0.9):
    from repro.core.decoding import DecodeConfig
    from repro.serving.engine import Request
    reqs = [
        Request(rid=i, prompt=b"Q: generate. A:",
                grammar=grammar if constrained else None,
                max_new_tokens=max_new,
                decode=DecodeConfig(method="sample",
                                    temperature=temperature),
                seed=seed0 + i)
        for i in range(n)
    ]
    return engine.generate(reqs)


def _error_counts(states, parser, grammar=None, table=None):
    complete = [s for s in states if s.finish_reason == "eos"]
    syntax_errors = sum(
        1 for s in states
        if not parser.recognize(s.generated))
    # like the paper (§6.3): length-truncated outputs count as compiler
    # errors even though SynCode keeps them valid PARTIAL programs —
    # report that invariant separately
    valid_partial = 0
    if grammar is not None:
        from repro.core.parser import IncrementalParser
        for s in states:
            try:
                IncrementalParser(grammar, table).partial_parse(s.generated)
                valid_partial += 1
            except Exception:
                pass
    return syntax_errors, len(complete), valid_partial


def table1_json(n=6, max_new=60):
    from repro.core.parser import IncrementalParser
    engine, bundles, tok = build_demo(("json",))
    g, tab, _ = bundles["json"]
    parser = IncrementalParser(g, tab)

    t0 = time.time()
    sync_states, sync_stats = _run_requests(engine, "json", n, max_new)
    sync_time = time.time() - t0
    t0 = time.time()
    std_states, std_stats = _run_requests(engine, "json", n, max_new,
                                          constrained=False)
    std_time = time.time() - t0

    sync_err, sync_done, _ = _error_counts(sync_states, parser)
    std_err, std_done, _ = _error_counts(std_states, parser)
    sync_complete_valid = sum(
        parser.recognize(s.generated) for s in sync_states
        if s.finish_reason == "eos")
    emit("table1_json_syncode", sync_time / n * 1e6,
         f"syntax_errors={sync_err}/{n};complete={sync_done};"
         f"valid_complete={sync_complete_valid}/{sync_done};"
         f"tok_s={sync_stats.tokens_per_sec:.1f}", stats=sync_stats)
    emit("table1_json_standard", std_time / n * 1e6,
         f"syntax_errors={std_err}/{n};"
         f"tok_s={std_stats.tokens_per_sec:.1f}", stats=std_stats)


def table1_python():
    """Table 1 carried to a real indentation-sensitive language:
    python_mini off/mask/strict with CPython ast.parse as the judge
    (benchmarks/bench_table1.py; masked rows must show 0 errors)."""
    from benchmarks import bench_table1
    if bench_table1.main() != 0:
        raise RuntimeError("bench_table1 reported syntax errors in a "
                           "masked mode")


def table2_sql(n=6, max_new=140):
    from repro.core.parser import IncrementalParser
    engine, bundles, tok = build_demo(("sql",))
    g, tab, _ = bundles["sql"]
    parser = IncrementalParser(g, tab)
    t0 = time.time()
    st, stats = _run_requests(engine, "sql", n, max_new)
    dt = time.time() - t0
    err, done, vp = _error_counts(st, parser, g, tab)
    toks = stats.tokens / max(1, n)
    t0 = time.time()
    st2, stats2 = _run_requests(engine, "sql", n, max_new,
                                constrained=False)
    dt2 = time.time() - t0
    err2, _, vp2 = _error_counts(st2, parser, g, tab)
    emit("table2_sql_syncode", dt / n * 1e6,
         f"syntax_errors={err}/{n};complete={done};"
         f"valid_partial={vp}/{n};avg_tokens={toks:.0f}", stats=stats)
    emit("table2_sql_standard", dt2 / n * 1e6,
         f"syntax_errors={err2}/{n};valid_partial={vp2}/{n}",
         stats=stats2)


def table3_gpl(n=6, max_new=140):
    from repro.core.parser import IncrementalParser
    for gname in ("minilang", "calc"):
        engine, bundles, tok = build_demo((gname,))
        g, tab, _ = bundles[gname]
        parser = IncrementalParser(g, tab)
        st, stats = _run_requests(engine, gname, n, max_new)
        err, done, vp = _error_counts(st, parser, g, tab)
        st2, _ = _run_requests(engine, gname, n, max_new,
                               constrained=False)
        err2, _, vp2 = _error_counts(st2, parser, g, tab)
        red = (1 - err / max(err2, 1)) * 100 if err2 else 100.0
        emit(f"table3_{gname}", stats.wall / max(stats.tokens, 1) * 1e6,
             f"syncode_errors={err}/{n};standard_errors={err2}/{n};"
             f"reduction={red:.0f}%;valid_partial={vp}vs{vp2}",
             stats=stats)


def table5_mask_store():
    from repro.core.grammars import load_grammar
    from repro.core.mask_store import build_mask_store
    from repro.core.tokenizer import ByteTokenizer
    for vocab in (512, 2048, 8192):
        tok = ByteTokenizer(vocab)
        for gname in ("json", "minilang"):
            g, tab = load_grammar(gname)
            t0 = time.time()
            store = build_mask_store(g, tok)
            dt = time.time() - t0
            emit(f"table5_store_{gname}_v{vocab}", dt * 1e6,
                 f"rows={store.num_rows};MB={store.nbytes()/1e6:.2f}")


def fig10_incremental():
    """Per-step parser cost, incremental vs from scratch, growing output."""
    from repro.core.grammars import load_grammar
    from repro.core.parser import IncrementalParser
    from repro.core.sampling import GrammarSampler
    g, tab = load_grammar("minilang")
    gs = GrammarSampler(g, seed=5)
    text = b" ".join(gs.sample_batch(12, budget=16, max_bytes=400))
    for mode, inc in (("incremental", True), ("scratch", False)):
        p = IncrementalParser(g, tab)
        t0 = time.time()
        steps = 0
        i = 8
        while i < min(len(text), 1200):
            p.partial_parse(text[:i], incremental=inc)
            i += 4
            steps += 1
        dt = (time.time() - t0) / steps
        emit(f"fig10_parse_{mode}", dt * 1e6, f"steps={steps}")


def mask_union_micro():
    """The paper's accelerator offload: fused mask gather+union+apply."""
    import jax.numpy as jnp
    from repro.kernels.masked_logits.kernel import masked_logits
    from repro.kernels.masked_logits.ref import masked_logits_ref
    rng = np.random.default_rng(0)
    B, V, R, A = 8, 2048, 2000, 32
    # jnp.asarray of fresh rng temporaries: nothing mutates the host
    # arrays afterwards, so CPU zero-copy aliasing is harmless here
    store = jnp.asarray(rng.integers(0, 2 ** 32, (R, V // 32),
                                     dtype=np.uint32))
    rows = jnp.asarray(rng.integers(-1, R, (B, A)).astype(np.int32))
    logits = jnp.asarray(rng.normal(size=(B, V)).astype(np.float32))
    eos = jnp.asarray(np.ones(B, bool))
    ref = jax.jit(masked_logits_ref)
    # reprolint: disable=RL003 deliberate timing bracket: this benchmark measures device wall time
    dt = timeit(lambda: jax.block_until_ready(
        ref(logits, store, rows, eos)), n=20)
    emit("mask_union_jnp_ref", dt * 1e6, f"B={B};V={V};A={A}")
    cd = jnp.zeros((B, V // 32), jnp.uint32)
    # reprolint: disable=RL003 deliberate timing bracket: this benchmark measures device wall time
    dt2 = timeit(lambda: jax.block_until_ready(
        masked_logits(logits, store, rows, eos, cd, block_v=2048,
                      interpret=True)), n=3)
    emit("mask_union_pallas_interpret", dt2 * 1e6,
         "interpret-mode (CPU correctness path; TPU is the target)")


def batched_engine_throughput(n=16, max_new=20):
    """Continuous batching vs the sequential round-robin baseline.

    Same n requests, same grammar, decode pool B in {1, 4, 16}. The
    sequential engine pays one [1, V] decode + one mask call + a host
    sync per request per token; the batched engine pays one [B, V]
    decode + one fused mask call per step for the whole pool, so
    tokens/sec must grow with B (the acceptance bar is B=16 beating
    sequential)."""
    from repro.core.decoding import DecodeConfig
    from repro.serving.engine import Request

    def reqs():
        return [Request(rid=i, prompt=b"Q: generate. A:", grammar="json",
                        max_new_tokens=max_new,
                        decode=DecodeConfig(method="sample",
                                            temperature=0.9),
                        seed=i) for i in range(n)]

    engine, bundles, tok = build_demo(("json",), slots=1)
    _, seq = engine.generate_sequential(reqs())     # warm jit via run 1
    _, seq = engine.generate_sequential(reqs())
    emit("engine_seq", seq.wall / max(seq.tokens, 1) * 1e6,
         f"tok_s={seq.tokens_per_sec:.1f};n={n}", stats=seq)
    for B in (1, 4, 16):
        engine, bundles, tok = build_demo(("json",), slots=B)
        engine.generate(reqs())                     # warm jit
        _, stats = engine.generate(reqs())
        emit(f"engine_batched_b{B}",
             stats.wall / max(stats.tokens, 1) * 1e6,
             f"tok_s={stats.tokens_per_sec:.1f};"
             f"decode_steps={stats.decode_steps};n={n}", stats=stats)


def opportunistic_ablation(n=4, max_new=50):
    for opp in (False, True):
        engine, bundles, tok = build_demo(("json",), opportunistic=opp)
        st, stats = _run_requests(engine, "json", n, max_new)
        emit(f"opportunistic_{'on' if opp else 'off'}",
             stats.wall / max(stats.tokens, 1) * 1e6,
             f"mask_computations={stats.mask_computations};"
             f"hits={stats.opportunistic_hits};tokens={stats.tokens}",
             stats=stats)


def speculative_engine_throughput(n=16, max_new=48):
    """Grammar-aware speculation vs the plain batched engine on JSON
    generation (ISSUE 2 acceptance: >= 1.3x tokens/s over
    engine_batched_b16, with jump-token fraction and draft acceptance
    rate in the CSV).

    Two workloads, both JSON and both through the same B=16 pool:
      * json      — generic RFC-8259 grammar, generations dominated by
                    free-text string/number regions (speculation's hard
                    case; drafts only).
      * jsonmsg   — compact schema-constrained records, where the grammar
                    determines braces/quotes/keys (speculation's home
                    turf; literal jump-forward + drafts).
    Each emits a matched plain-engine baseline row so the speedup is
    apples-to-apples (same grammar, same greedy decode, same requests)."""
    from repro.core.decoding import DecodeConfig
    from repro.serving.engine import Request
    from repro.spec import SpecConfig

    def reqs(gname):
        return [Request(rid=i, prompt=b"Q: generate. A:", grammar=gname,
                        max_new_tokens=max_new,
                        decode=DecodeConfig(method="greedy"), seed=i)
                for i in range(n)]

    for gname, spec in (("json", SpecConfig()),
                        ("jsonmsg", SpecConfig(literal_jump=True))):
        engine, bundles, tok = build_demo((gname,), slots=16)
        engine.generate(reqs(gname))                        # warm jit
        _, base = engine.generate(reqs(gname))
        engine.generate_speculative(reqs(gname), spec=spec)  # warm jit
        _, st = engine.generate_speculative(reqs(gname), spec=spec)
        emit(f"engine_spec_baseline_{gname}_b16",
             base.wall / max(base.tokens, 1) * 1e6,
             f"tok_s={base.tokens_per_sec:.1f};"
             f"decode_steps={base.decode_steps};n={n}", stats=base)
        emit(f"engine_spec_{gname}_b16",
             st.wall / max(st.tokens, 1) * 1e6,
             f"tok_s={st.tokens_per_sec:.1f};"
             f"decode_steps={st.decode_steps};"
             f"jump_frac={st.jump_fraction:.2f};"
             f"accept_rate={st.acceptance_rate:.2f};"
             f"speedup_vs_plain={st.tokens_per_sec / base.tokens_per_sec:.2f}x;"
             f"n={n}", stats=st)


def paged_engine_sharedprefix(n=32, max_new=24):
    """Paged KV engine vs the dense batched engine on a SHARED-PREFIX
    workload at equal KV memory budget (ISSUE 3 acceptance: >= 1.3x
    tokens/sec over the dense b16 engine at equal memory, equivalently
    >= 2x pool size at equal memory, prefix_hit_rate > 0 in the CSV).

    All n requests carry the same long jsonmsg schema prompt — the
    constrained-serving common case (one schema/system prompt, short
    per-request tails). Three rows:

      engine_batched_b16_sharedprefix  dense pool, 16 slots — its
          [16, max_len] caches ARE the memory budget (6400 KV slots);
          prefills and stores the prefix once per request.
      engine_paged_b16_sharedprefix    paged, same 16 slots, same
          budget (400 pages x 16): prefix prefilled/stored once,
          chunked-prefill admission; shows hit rate + pages/request.
      engine_paged_b32_eqmem_sharedprefix  the payoff row: prefix
          sharing means 32 slots fit the SAME 6400-slot budget (peak
          utilization stays well under 1), and doubling the pool width
          at fixed memory is where paging turns into tokens/sec."""
    from repro.core.decoding import DecodeConfig
    from repro.serving.engine import Request

    prompt = (b'sys: emit compact msg records like '
              b'{"type": "x", "seq": 1, "body": "abc"} '
              b'with single-digit seq and short lowercase body. ' * 2
              )[:192]

    def reqs():
        return [Request(rid=i, prompt=prompt, grammar="jsonmsg",
                        max_new_tokens=max_new,
                        decode=DecodeConfig(method="greedy"), seed=i)
                for i in range(n)]

    dense, _, _ = build_demo(("jsonmsg",), slots=16)
    dense.generate(reqs())                          # warm jit
    _, base = dense.generate(reqs())
    emit("engine_batched_b16_sharedprefix",
         base.wall / max(base.tokens, 1) * 1e6,
         f"tok_s={base.tokens_per_sec:.1f};"
         f"decode_steps={base.decode_steps};"
         f"prompt_len={len(prompt)};n={n}", stats=base)

    def kv_cols(st):
        return (f"prefix_hit_rate={st.prefix_hit_rate:.2f};"
                f"kv_pages_in_use={st.kv_pages_in_use};"
                f"kv_peak_utilization={st.kv_peak_utilization:.3f};"
                f"pages_per_req="
                f"{st.kv_page_allocs / max(st.requests, 1):.1f}")

    for slots, name in ((16, "engine_paged_b16_sharedprefix"),
                        (32, "engine_paged_b32_eqmem_sharedprefix")):
        paged, _, _ = build_demo(("jsonmsg",), slots=slots, paged=True,
                                 page_size=16, num_pages=400)
        paged.generate(reqs())                      # warm jit
        _, st = paged.generate(reqs())
        emit(name, st.wall / max(st.tokens, 1) * 1e6,
             f"tok_s={st.tokens_per_sec:.1f};"
             f"decode_steps={st.decode_steps};"
             f"speedup_vs_dense="
             f"{st.tokens_per_sec / base.tokens_per_sec:.2f}x;"
             f"{kv_cols(st)};n={n}", stats=st)


def async_engine_throughput():
    """Async/streaming engine rows: engine_async_b16_{sampled,greedy}_
    overlap_{off,on} + sync twins (benchmarks/bench_async.py) — the
    persistent step loop vs the sync engine, host/device overlap off
    and on, identity asserted per run."""
    from benchmarks import bench_async
    if bench_async.main(smoke=False) != 0:
        raise RuntimeError("bench_async reported identity violation")


def sharded_engine_throughput():
    """Tensor-parallel (vocab-sharded) engine rows: engine_sharded_m1 /
    _m2 / _m4 + an unsharded baseline (docs/sharding.md), each asserting
    token-for-token identity with the baseline.

    Runs benchmarks/bench_sharded.py in a SUBPROCESS — the main bench
    process must keep the single real CPU device (tests/conftest.py
    note), and the device count is fixed at backend init. The
    subprocess forces its own XLA host devices before importing jax."""
    import os
    import subprocess
    import sys
    root = os.path.join(os.path.dirname(__file__), "..")
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_sharded"],
        cwd=root, capture_output=True, text=True, timeout=1800)
    sys.stdout.write(out.stdout)
    # re-absorb the subprocess CSV rows into this process's artifact
    for line in out.stdout.splitlines():
        collect_line(line)
    if out.returncode != 0:
        sys.stderr.write(out.stderr)
        raise RuntimeError("bench_sharded subprocess failed")


def assert_rows_complete(rows) -> None:
    """Every artifact row must carry the full attribution column set and
    a resolvable run identity — the regression observatory refuses to
    persist rows it can't later diff or attribute."""
    from .common import ATTRIBUTION_COLS, run_meta_dict
    meta = run_meta_dict()
    assert meta.get("git_sha"), "run_meta missing git_sha"
    assert meta.get("jax_version"), "run_meta missing jax_version"
    for row in rows:
        missing = [c for c in ATTRIBUTION_COLS
                   if c not in row.get("attribution", {})]
        assert not missing, \
            f"row {row.get('name')!r} missing attribution cols {missing}"
        assert "name" in row and "us_per_call" in row, f"malformed row {row}"


ALL = [table1_json, table1_python, table2_sql, table3_gpl,
       table5_mask_store,
       fig10_incremental, mask_union_micro, opportunistic_ablation,
       batched_engine_throughput, speculative_engine_throughput,
       paged_engine_sharedprefix, async_engine_throughput,
       sharded_engine_throughput]
