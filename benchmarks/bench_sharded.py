"""Sharded (tensor-parallel) engine benchmark rows.

Emits `engine_sharded_m{1,2,4}` CSV rows — the vocab-parallel serving
engine (docs/sharding.md) at mesh sizes 1, 2 and 4 — next to an
unsharded baseline row, and asserts token-for-token identity with the
baseline on every run (the bit-exactness contract is part of the
benchmark, not just the test suite).

Run it standalone (`python -m benchmarks.bench_sharded [--smoke]`): it
forces XLA host devices BEFORE jax loads. `benchmarks/run.py` shells
out to it so the main bench process keeps the single real CPU device.
`--smoke` is the seconds-scale CI gate wired into `make bench-smoke`.
"""
from __future__ import annotations

import os
import sys

# must precede any jax import in this process
_flags = os.environ.get("XLA_FLAGS", "")
if "xla_force_host_platform_device_count" not in _flags:
    os.environ["XLA_FLAGS"] = (
        _flags + " --xla_force_host_platform_device_count=4").strip()

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import time

from benchmarks.common import build_demo, emit

MESHES = (1, 2, 4)


def _reqs(n, max_new):
    from repro.core.decoding import DecodeConfig
    from repro.serving.engine import Request
    return [Request(rid=i, prompt=b"Q: generate. A:", grammar="json",
                    max_new_tokens=max_new,
                    decode=DecodeConfig(method="sample", temperature=0.9),
                    seed=i) for i in range(n)]


def main(smoke: bool = False) -> int:
    import jax
    n, max_new, slots = (6, 8, 4) if smoke else (16, 20, 8)
    meshes = (2,) if smoke else MESHES

    base, _, _ = build_demo(("json",), slots=slots)
    base.generate(_reqs(n, max_new))                     # warm jit
    bstates, bstats = base.generate(_reqs(n, max_new))
    want = [s.token_ids for s in bstates]
    emit("engine_sharded_base", bstats.wall / max(bstats.tokens, 1) * 1e6,
         f"tok_s={bstats.tokens_per_sec:.1f};mesh=none;n={n}",
         stats=bstats)

    ok = bstats.tokens > 0
    for m in meshes:
        if jax.device_count() < m:
            # e.g. an inherited XLA_FLAGS pinned a smaller device count:
            # an unreachable mesh size is a skip, not a failure — only
            # identity violations fail the run
            emit(f"engine_sharded_m{m}", 0,
                 f"SKIPPED;devices={jax.device_count()}")
            continue
        eng, _, _ = build_demo(("json",), slots=slots, mesh=m)
        eng.generate(_reqs(n, max_new))                  # warm jit
        states, stats = eng.generate(_reqs(n, max_new))
        identical = [s.token_ids for s in states] == want
        ok = ok and identical and stats.tokens == bstats.tokens
        emit(f"engine_sharded_m{m}",
             stats.wall / max(stats.tokens, 1) * 1e6,
             f"tok_s={stats.tokens_per_sec:.1f};"
             f"mesh_devices={stats.mesh_devices};"
             f"identical_to_base={identical};"
             f"speedup_vs_base="
             f"{stats.tokens_per_sec / max(bstats.tokens_per_sec, 1e-9):.2f}x;"
             f"n={n}", stats=stats)
    if smoke:
        print(f"bench-sharded-smoke: {'OK' if ok else 'FAILED'} "
              f"({bstats.tokens} tokens, identity "
              f"{'held' if ok else 'VIOLATED'})")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(smoke="--smoke" in sys.argv[1:]))
