"""Benchmark driver: one function per paper table (+ substrate micro-
benches). Prints ``name,us_per_call,derived`` CSV, then the roofline
table if dry-run artifacts exist."""
from __future__ import annotations

import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    print("name,us_per_call,derived")
    from benchmarks import bench_tables
    for fn in bench_tables.ALL:
        try:
            fn()
        except Exception:
            print(f"{fn.__name__},0,ERROR")
            traceback.print_exc()
    # roofline table (requires dry-run artifacts)
    try:
        from benchmarks import roofline
        recs = roofline.load_records()
        if recs:
            print("\n=== roofline (from dry-run artifacts) ===")
            roofline.main()
    except Exception:
        traceback.print_exc()


if __name__ == "__main__":
    main()
