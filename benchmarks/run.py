"""Benchmark driver: one function per paper table (+ substrate micro-
benches). Prints ``name,us_per_call,derived`` CSV, then the roofline
table if dry-run artifacts exist.

By default also writes the schema-versioned JSON artifact
(``artifacts/bench/BENCH_<git-sha>.json``) consumed by
scripts/bench_diff.py; disable with ``--no-json-out``.
"""
from __future__ import annotations

import argparse
import os
import sys
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json-out", metavar="PATH", default=None,
                    help="artifact path (default: "
                         "artifacts/bench/BENCH_<git-sha>.json)")
    ap.add_argument("--no-json-out", action="store_true",
                    help="skip writing the JSON artifact")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench-fn names to run")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    from benchmarks import bench_tables, common
    errors = 0
    only = set(args.only.split(",")) if args.only else None
    for fn in bench_tables.ALL:
        if only is not None and fn.__name__ not in only:
            continue
        try:
            fn()
        except Exception:
            errors += 1
            print(f"{fn.__name__},0,ERROR")
            traceback.print_exc()
    bench_tables.assert_rows_complete(common.rows())
    if not args.no_json_out:
        path = common.write_artifact(args.json_out)
        print(f"\nwrote {len(common.rows())} rows -> {path}",
              file=sys.stderr)
    # roofline table (requires dry-run artifacts)
    try:
        from benchmarks import roofline
        recs = roofline.load_records()
        if recs:
            print("\n=== roofline (from dry-run artifacts) ===")
            roofline.main()
    except Exception:
        traceback.print_exc()
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
