"""Roofline report: aggregates artifacts/dryrun/*.json into the per-
(arch x shape x mesh) table used by EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_records(mesh=None):
    recs = []
    for fn in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def fmt_table(recs, include_skipped=True):
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':8s} {'fits':5s} "
           f"{'hbm':>5s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'bound':>8s} {'useful':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        if "skipped" in r:
            if include_skipped:
                lines.append(f"{r['arch']:22s} {r['shape']:12s} "
                             f"{r['mesh']:8s} SKIP  ({r['skipped'][:60]})")
            continue
        t = r["roofline"]
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
            f"{'yes' if r['fits_hbm'] else 'NO':5s} "
            f"{r['hbm_utilization']:5.2f} "
            f"{t['compute_s']:10.3e} {t['memory_s']:10.3e} "
            f"{t['collective_s']:10.3e} {t['bottleneck'][:-2]:>8s} "
            f"{r['useful_flops_ratio']:7.3f}")
    return "\n".join(lines)


def position(measured_s: float, calls: int, flops_per_call: float,
             hbm_bytes_per_call: float,
             peak_flops: float = 0.0, peak_bw: float = 0.0) -> dict:
    """Place a MEASURED device interval (obs/devtime bracket) on the
    roofline spanned by a static hlo_cost estimate.

    Returns achieved FLOP/s and bytes/s, the arithmetic intensity of
    the fn, and — when hardware peaks are given — the fraction of the
    roof actually reached (max of the compute and bandwidth fractions:
    a fn pinned at 80% of either peak is 80% roofline-efficient). The
    bench artifact writer stores this per attribution column so
    bench_diff can flag efficiency regressions, not just latency ones.
    """
    if measured_s <= 0.0 or calls <= 0:
        return {"achieved_flops_per_s": 0.0, "achieved_bytes_per_s": 0.0,
                "intensity_flops_per_byte": 0.0, "roof_fraction": 0.0}
    per_call = measured_s / calls
    out = {
        "achieved_flops_per_s": flops_per_call / per_call,
        "achieved_bytes_per_s": hbm_bytes_per_call / per_call,
        "intensity_flops_per_byte": (flops_per_call
                                     / max(hbm_bytes_per_call, 1.0)),
    }
    fracs = []
    if peak_flops > 0.0:
        fracs.append(out["achieved_flops_per_s"] / peak_flops)
    if peak_bw > 0.0:
        fracs.append(out["achieved_bytes_per_s"] / peak_bw)
    out["roof_fraction"] = max(fracs) if fracs else 0.0
    return out


def main():
    recs = load_records()
    print(fmt_table(recs))
    ok = [r for r in recs if "skipped" not in r]
    fits = sum(1 for r in ok if r["fits_hbm"])
    print(f"\n{len(ok)} compiled, {fits} fit HBM, "
          f"{len(recs) - len(ok)} documented skips")


if __name__ == "__main__":
    main()
