"""Roofline report: aggregates artifacts/dryrun/*.json into the per-
(arch x shape x mesh) table used by EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def load_records(mesh=None):
    recs = []
    for fn in sorted(glob.glob(os.path.join(ART, "*.json"))):
        with open(fn) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        recs.append(r)
    return recs


def fmt_table(recs, include_skipped=True):
    hdr = (f"{'arch':22s} {'shape':12s} {'mesh':8s} {'fits':5s} "
           f"{'hbm':>5s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'bound':>8s} {'useful':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        if "skipped" in r:
            if include_skipped:
                lines.append(f"{r['arch']:22s} {r['shape']:12s} "
                             f"{r['mesh']:8s} SKIP  ({r['skipped'][:60]})")
            continue
        t = r["roofline"]
        lines.append(
            f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:8s} "
            f"{'yes' if r['fits_hbm'] else 'NO':5s} "
            f"{r['hbm_utilization']:5.2f} "
            f"{t['compute_s']:10.3e} {t['memory_s']:10.3e} "
            f"{t['collective_s']:10.3e} {t['bottleneck'][:-2]:>8s} "
            f"{r['useful_flops_ratio']:7.3f}")
    return "\n".join(lines)


def main():
    recs = load_records()
    print(fmt_table(recs))
    ok = [r for r in recs if "skipped" not in r]
    fits = sum(1 for r in ok if r["fits_hbm"])
    print(f"\n{len(ok)} compiled, {fits} fit HBM, "
          f"{len(recs) - len(ok)} documented skips")


if __name__ == "__main__":
    main()
