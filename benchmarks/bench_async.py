"""Async engine + host/device overlap benchmark rows (ISSUE 5).

Two workloads through the persistent async step loop, each measured
with overlap off and on and ASSERTED token-for-token identical to the
synchronous engine (the overlap speedup may never buy a different
stream):

  * `sampled` — the `engine_batched_b16` twin (16 JSON requests,
    temperature 0.9, B = 16). High-temperature sampling over the
    over-approximate mask rejects some slot most steps, so the adaptive
    gate (serving/loop.py::DenseMode) quickly stops speculating —
    overlap-on must track overlap-off, not lose to it.
  * `greedy`  — the steady-state structured-output serving case (same
    requests, greedy). The masked argmax almost always passes the exact
    oracle, so nearly every speculative forward is consumed and
    overlap-on shows the throughput win: the device never idles while
    the host steps the incremental parsers and builds mask rows.

The overlap comparison uses PAIRED INTERLEAVED trials (off, on, off,
on, ...) and reports the median paired ratio: the effect lives at the
few-percent level on this substrate — the incremental parsers keep host
grammar work at ~2-4 ms of a ~45 ms step, so hiding all of it buys a
few percent here, while the same mechanism hides 10-30% mask-generation
shares on accelerator-scale vocabularies (the regime the ISSUE targets)
— and a paired design is how a few-percent effect stays measurable on a
noisy shared box.

`--smoke` is the seconds-scale CI gate wired into `make bench-smoke`.
"""
from __future__ import annotations

import asyncio
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import build_demo, emit


def _reqs(n, max_new, method, temperature=0.9):
    from repro.core.decoding import DecodeConfig
    from repro.serving.engine import Request
    return [Request(rid=i, prompt=b"Q: generate. A:", grammar="json",
                    max_new_tokens=max_new,
                    decode=DecodeConfig(method=method,
                                        temperature=temperature),
                    seed=i) for i in range(n)]


def _run_async(engine, reqs):
    """Returns (states, stats, lifecycle_summary) — the summary carries
    the per-request latency histograms (TTFT / inter-token / queue wait)
    the CSV rows report as p50/p99 (docs/observability.md)."""
    from repro.serving.async_engine import AsyncEngine

    async def go():
        aeng = AsyncEngine(engine)
        try:
            states, stats = await aeng.generate(reqs)
            return states, stats, aeng.telemetry.lifecycle.summary()
        finally:
            await aeng.drain()
    return asyncio.run(go())


def _lat_cols(summary) -> str:
    """ttft/itl p50/p99 columns (ms) from a lifecycle summary."""
    out = []
    for key in ("ttft", "itl"):
        h = summary.get(key) or {}
        for q in ("p50", "p99"):
            v = h.get(q)
            out.append(f"{key}_{q}_ms="
                       f"{v * 1e3:.2f}" if v is not None
                       else f"{key}_{q}_ms=nan")
    return ";".join(out)


PAIRS = 5


def _median(xs):
    xs = sorted(xs)
    return xs[len(xs) // 2]


def main(smoke: bool = False) -> int:
    n, max_new, slots = (4, 10, 4) if smoke else (16, 32, 16)
    pairs = 1 if smoke else PAIRS
    tag = f"b{slots}"
    ok = True
    win = {}

    for wname, method in (("sampled", "sample"), ("greedy", "greedy")):
        sync_eng, _, _ = build_demo(("json",), slots=slots)
        sync_eng.generate(_reqs(n, max_new, method))     # warm jit
        sstates, sstats = sync_eng.generate(_reqs(n, max_new, method))
        want = [s.token_ids for s in sstates]
        emit(f"engine_sync_{tag}_{wname}",
             sstats.wall / max(sstats.tokens, 1) * 1e6,
             f"tok_s={sstats.tokens_per_sec:.1f};"
             f"decode_steps={sstats.decode_steps};n={n}", stats=sstats)
        ok = ok and sstats.tokens > 0

        engines = {}
        for oname, overlap in (("overlap_off", False),
                               ("overlap_on", True)):
            engines[oname], _, _ = build_demo(("json",), slots=slots,
                                              overlap=overlap)
            _run_async(engines[oname], _reqs(n, max_new, method))  # warm
        rates = {"overlap_off": [], "overlap_on": []}
        ident = {"overlap_off": True, "overlap_on": True}
        stats_of = {}
        lat_of = {}
        for _ in range(pairs):          # paired, interleaved trials
            for oname in ("overlap_off", "overlap_on"):
                states, stats, lat = _run_async(
                    engines[oname], _reqs(n, max_new, method))
                by_rid = {s.req.rid: s.token_ids for s in states}
                identical = [by_rid[i] for i in range(n)] == want
                ok = ok and identical
                ident[oname] = ident[oname] and identical
                rates[oname].append(stats.tokens_per_sec)
                stats_of[oname] = stats
                lat_of[oname] = lat
        for oname in ("overlap_off", "overlap_on"):
            stats = stats_of[oname]
            tok_s = _median(rates[oname])
            emit(f"engine_async_{tag}_{wname}_{oname}",
                 1e6 / max(tok_s, 1e-9),
                 f"tok_s={tok_s:.1f};"
                 f"decode_steps={stats.decode_steps};"
                 f"overlap_hits={stats.overlap_hits}/"
                 f"{stats.overlap_dispatched};"
                 f"{_lat_cols(lat_of[oname])};"
                 f"identical_to_sync={ident[oname]};"   # AND over trials
                 f"pairs={pairs};n={n}", stats=stats)
        speedup = _median([t / max(f, 1e-9) for f, t in
                           zip(rates["overlap_off"],
                               rates["overlap_on"])])
        win[wname] = speedup
        on = stats_of["overlap_on"]
        emit(f"engine_async_{tag}_{wname}_overlap_speedup", speedup * 100,
             f"overlap_on_vs_off={speedup:.2f}x_paired_median;"
             f"hit_rate={on.overlap_hit_rate:.2f}")

    if smoke:
        print(f"bench-async-smoke: {'OK' if ok else 'FAILED'} "
              f"(identity {'held' if ok else 'VIOLATED'}; overlap "
              f"greedy {win.get('greedy', 0):.2f}x, sampled "
              f"{win.get('sampled', 0):.2f}x)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(smoke="--smoke" in sys.argv[1:]))
