"""Table 1 on a REAL language (python_mini): syntax errors by grammar
mode, checked with CPython's own `ast.parse` — not just our parser.

Three rows, same model, same prompts, same seeds:

  table1_python_off            unconstrained decode (the paper's
                               "standard" baseline; errors expected)
  table1_python_grammar_mask   SynCode overapproximate masking
  table1_python_grammar_strict terminal-boundary-aligned masking

For both masked rows every COMPLETE (eos) output must pass `ast.parse`
— zero syntax errors, the paper's Table 1 claim carried to a real
indentation-sensitive language — and every length-truncated output must
still be a valid PARTIAL program (the SynCode invariant the paper's
error counts hide). Exits non-zero otherwise (`--smoke` is the CI
gate).
"""
from __future__ import annotations

import ast
import sys
import time

from .common import build_demo, emit


def _ast_ok(data: bytes) -> bool:
    try:
        ast.parse(data.decode("ascii"))
    except (SyntaxError, ValueError, UnicodeDecodeError):
        return False
    return True


def _partial_ok(grammar, table, data: bytes) -> bool:
    from repro.core.parser import IncrementalParser
    try:
        IncrementalParser(grammar, table).partial_parse(data)
    except Exception:
        return False
    return True


def main(n=6, max_new=80, smoke=False) -> int:
    from repro.core.decoding import DecodeConfig
    from repro.serving.engine import Request

    if smoke:
        n, max_new = 4, 40
    engine, bundles, tok = build_demo(("python_mini",), vocab=1024,
                                      max_len=max(96, max_new + 32))
    g, tab, _ = bundles["python_mini"]

    ok = True
    for label, grammar, mode in (
            ("off", None, None),
            ("grammar_mask", "python_mini", "grammar_mask"),
            ("grammar_strict", "python_mini", "grammar_strict")):
        reqs = [Request(rid=i, prompt=b"# write code\n", grammar=grammar,
                        grammar_mode=mode, max_new_tokens=max_new,
                        decode=DecodeConfig(method="sample",
                                            temperature=0.9),
                        seed=100 + i) for i in range(n)]
        t0 = time.time()
        states, stats = engine.generate(reqs)
        wall = time.time() - t0

        complete = [s for s in states if s.finish_reason == "eos"]
        ast_errors = sum(1 for s in complete if not _ast_ok(s.generated))
        # unconstrained truncated outputs are judged by ast too (they are
        # just invalid); masked truncated outputs must be valid partials
        if grammar is None:
            ast_errors += sum(1 for s in states if s.finish_reason != "eos"
                              and not _ast_ok(s.generated))
        partial_valid = sum(1 for s in states
                            if _partial_ok(g, tab, s.generated))
        emit(f"table1_python_{label}", wall / n * 1e6,
             f"ast_errors={ast_errors}/{n};complete={len(complete)};"
             f"valid_partial={partial_valid}/{n};"
             f"tok_s={stats.tokens_per_sec:.1f}", stats=stats)
        if grammar is not None:
            if ast_errors:
                print(f"bench_table1: {label} produced {ast_errors} "
                      f"ast-rejected COMPLETE outputs (must be 0)")
                ok = False
            if partial_valid != n:
                print(f"bench_table1: {label} produced "
                      f"{n - partial_valid} invalid partial outputs")
                ok = False
    print(f"bench_table1: {'OK' if ok else 'FAILED'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main(smoke="--smoke" in sys.argv))
