# Developer entrypoints. PYTHONPATH=src is the repo's import convention.

PY ?= python

.PHONY: test lint bench bench-smoke bench-regress obs-smoke docs-check

test:              ## tier-1 test suite (same command CI runs)
	PYTHONPATH=src $(PY) -m pytest -x -q

lint:              ## reprolint: AST invariant analyzer over src/ + benchmarks/ + scripts/ (CI gate; rule catalog in docs/static_analysis.md)
	$(PY) scripts/reprolint.py

bench:             ## paper-table + engine benchmarks (CSV to stdout)
	PYTHONPATH=src $(PY) benchmarks/run.py

bench-smoke:       ## seconds-scale paged + sharded + async engine smoke runs (CI gate)
	PYTHONPATH=src $(PY) -m benchmarks.bench_smoke
	PYTHONPATH=src $(PY) -m benchmarks.bench_table1 --smoke
	PYTHONPATH=src $(PY) -m benchmarks.bench_sharded --smoke
	PYTHONPATH=src $(PY) -m benchmarks.bench_async --smoke

bench-regress:     ## perf-regression gate: smoke artifact vs committed baseline (warn-only) + bench_diff self-test (hard gate)
	PYTHONPATH=src $(PY) -m benchmarks.bench_smoke --json-out artifacts/bench/BENCH_smoke_current.json
	$(PY) scripts/bench_diff.py benchmarks/baselines/BENCH_smoke.json artifacts/bench/BENCH_smoke_current.json --warn-only
	$(PY) scripts/bench_diff.py --self-test benchmarks/baselines/BENCH_smoke.json

obs-smoke:         ## end-to-end telemetry gate: HTTP server + /metrics + trace dump (CI gate)
	PYTHONPATH=src $(PY) scripts/obs_smoke.py

docs-check:        ## fail if src/repro packages are missing from README's module map
	$(PY) scripts/docs_check.py
