"""Batched serving across all builtin grammars at once: each request carries
own grammar; the engine keeps per-request incremental parser state and
shares the model — the compound-AI-system scenario from the paper's
introduction (JSON for tools, SQL for a database, a DSL for a calculator,
a GPL for codegen).

    PYTHONPATH=src python examples/serve_multigrammar.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config
from repro.core.decoding import DecodeConfig
from repro.core.grammars import BUILTIN, load_grammar
from repro.core.mask_store import build_mask_store
from repro.core.parser import IncrementalParser
from repro.core.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.serving.engine import Engine, Request


def main():
    cfg = get_config("syncode-demo")
    tok = ByteTokenizer(cfg.vocab_size)
    bundles = {}
    for name in BUILTIN:
        g, tab = load_grammar(name)
        bundles[name] = (g, tab, build_mask_store(g, tok, verbose=True))

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, tok, bundles, max_len=300,
                    opportunistic=True)

    prompts = {
        "json": b"Tool call arguments:",
        "sql": b"Query the singers table:",
        "calc": b"Compute the area:",
        "minilang": b"Write a helper:",
        "jsonmsg": b"Emit records:",
    }
    reqs = []
    for i, (gname, prompt) in enumerate(sorted(prompts.items()) * 2):
        reqs.append(Request(rid=i, prompt=prompt, grammar=gname,
                            max_new_tokens=60,
                            decode=DecodeConfig(method="sample",
                                                temperature=0.85),
                            seed=i))
    states, stats = engine.generate(reqs)

    print(f"\n{'grammar':9s} {'finish':9s} valid  output")
    total_valid = 0
    complete = 0
    for st in states:
        g, tab, _ = bundles[st.req.grammar]
        p = IncrementalParser(g, tab)
        ok = p.recognize(st.generated)
        if st.finish_reason == "eos":
            complete += 1
            total_valid += ok
        print(f"{st.req.grammar:9s} {st.finish_reason:9s} {str(ok):5s}  "
              f"{st.generated[:50]!r}")
    print(f"\ncompleted-and-valid: {total_valid}/{complete} | "
          f"{stats.tokens_per_sec:.1f} tok/s | opportunistic hits "
          f"{stats.opportunistic_hits}/{stats.tokens}")


if __name__ == "__main__":
    main()
