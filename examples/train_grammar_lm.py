"""End-to-end driver: TRAIN a small LM on grammar-sampled calc-DSL data,
then serve it with and without SynCode and compare syntax validity +
(crude) semantic quality — the full paper loop on one CPU.

    PYTHONPATH=src python examples/train_grammar_lm.py [--steps 300]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config
from repro.core.decoding import DecodeConfig
from repro.core.grammars import load_grammar
from repro.core.mask_store import build_mask_store
from repro.core.parser import IncrementalParser
from repro.core.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.serving.engine import Engine, Request
from repro.training.data import GrammarDataPipeline
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--grammar", default="calc")
    args = ap.parse_args()

    cfg = get_config("syncode-demo")
    tok = ByteTokenizer(cfg.vocab_size)
    g, tab = load_grammar(args.grammar)
    store = build_mask_store(g, tok)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    print(f"== training {cfg.name} on {args.grammar} samples ==")
    data = iter(GrammarDataPipeline(g, tok, seq_len=96, batch_size=8,
                                    seed=0))
    params, result = train(
        model, params, data, steps=args.steps,
        opt_cfg=AdamWConfig(lr=2e-3, warmup_steps=20,
                            total_steps=args.steps),
        log_every=max(1, args.steps // 8))

    print("\n== serving: standard vs SynCode ==")
    engine = Engine(model, params, tok, {args.grammar: (g, tab, store)},
                    max_len=200)
    parser = IncrementalParser(g, tab)
    for label, gname in (("standard", None), ("syncode", args.grammar)):
        reqs = [Request(rid=i, prompt=b"", grammar=gname,
                        max_new_tokens=48,
                        decode=DecodeConfig(method="sample",
                                            temperature=0.8),
                        seed=10 + i) for i in range(6)]
        states, stats = engine.generate(reqs)
        valid = sum(parser.recognize(s.generated) for s in states)
        complete = sum(s.finish_reason == "eos" for s in states)
        print(f"{label:9s}: valid {valid}/6, complete {complete}/6, "
              f"{stats.tokens_per_sec:.1f} tok/s")
        for s in states[:2]:
            print(f"   {s.generated[:64]!r}")


if __name__ == "__main__":
    main()
