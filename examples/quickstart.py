"""Quickstart: grammar-constrained generation in ~40 lines.

Builds the offline artifacts (grammar -> LR table -> DFA mask store),
wraps a small LM with the SynCode constraint, and generates JSON that is
guaranteed syntactically valid whenever generation completes.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs import get_config
from repro.core.constrain import GrammarConstraint
from repro.core.decoding import DecodeConfig
from repro.core.grammars import load_grammar
from repro.core.mask_store import build_mask_store
from repro.core.parser import IncrementalParser
from repro.core.tokenizer import ByteTokenizer
from repro.models.model import build_model
from repro.serving.engine import Engine, Request


def main():
    # --- offline: grammar -> parser tables + DFA mask store -------------
    grammar, table = load_grammar("json")
    tokenizer = ByteTokenizer(2048)
    store = build_mask_store(grammar, tokenizer, verbose=True)

    # --- peek at the mechanism (paper Fig. 1) ---------------------------
    gc = GrammarConstraint(grammar, table, store, tokenizer)
    for prefix in (b"", b'{"name', b'{"a": [1, 2', b'{"a": 1}'):
        sm = gc.step_rows(prefix)
        mask = gc.token_mask(prefix)
        allowed = np.where(mask)[0]
        ex = [tokenizer.id_to_bytes[t] for t in allowed[:5]]
        print(f"C_k={prefix!r:16} |A|={sm.num_sequences:2d} "
              f"allowed={len(allowed):4d} eos={sm.eos_allowed} e.g. {ex}")

    # --- online: constrained generation with a (random-init) LM --------
    model = build_model(get_config("syncode-demo"))
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, tokenizer,
                    {"json": (grammar, table, store)}, max_len=300)
    reqs = [Request(rid=i, prompt=b"Return JSON:", grammar="json",
                    max_new_tokens=60,
                    decode=DecodeConfig(method="sample", temperature=0.8),
                    seed=i) for i in range(3)]
    states, stats = engine.generate(reqs, verbose=True)

    parser = IncrementalParser(grammar, table)
    for st in states:
        ok = parser.recognize(st.generated)
        print(f"req {st.req.rid}: finish={st.finish_reason:8s} "
              f"valid={ok} -> {st.generated[:60]!r}")
    print(f"\n{stats.tokens_per_sec:.1f} tok/s "
          f"(mask: {stats.mask_time:.2f}s/{stats.mask_computations} steps)")


if __name__ == "__main__":
    main()
